"""Config registry: published sizes, smoke reductions, cell applicability."""
import pytest

from repro.configs import (ASSIGNED, SHAPES, cell_applicable, get_config,
                           list_archs, smoke_config)

# published parameter counts (billions), loose tolerance: our analytic count
# skips small terms (biases, conv taps)
PUBLISHED = {
    "mistral-nemo-12b": (12.2, 0.1),
    "llama3.2-3b": (3.2, 0.15),
    "gemma-7b": (8.5, 0.1),       # gemma counts embeddings once (tied)
    "starcoder2-3b": (3.0, 0.15),
    "qwen2-vl-72b": (72.7, 0.1),
    "dbrx-132b": (131.6, 0.05),
    "mamba2-130m": (0.13, 0.15),
    "granite-moe-3b-a800m": (3.3, 0.15),
    "recurrentgemma-9b": (8.5, 0.15),
    "whisper-medium": (0.66, 0.25),
    "multihyena-153m": (0.21, 0.4),
}


def test_all_assigned_registered():
    for a in ASSIGNED:
        assert get_config(a).name == a
    assert len(ASSIGNED) == 10


@pytest.mark.parametrize("arch", sorted(PUBLISHED))
def test_param_counts_match_published(arch):
    target, tol = PUBLISHED[arch]
    n = get_config(arch).n_params() / 1e9
    assert abs(n - target) / target < tol, (arch, n, target)


def test_moe_active_params():
    c = get_config("dbrx-132b")
    assert c.n_active_params() < 0.4 * c.n_params()
    g = get_config("granite-moe-3b-a800m")
    assert g.n_active_params() < 0.5 * g.n_params()


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_smoke_reduction_preserves_family(arch):
    cfg = get_config(arch)
    sm = smoke_config(cfg)
    assert sm.family == cfg.family
    assert sm.pattern == cfg.pattern
    assert sm.mlp_kind == cfg.mlp_kind
    assert (sm.moe is None) == (cfg.moe is None)
    assert sm.n_params() < 0.02 * max(cfg.n_params(), 1)


def test_long_context_applicability():
    # pure attention archs skip long_500k; ssm/hybrid/lcsm run it
    assert not cell_applicable(get_config("llama3.2-3b"), SHAPES["long_500k"])[0]
    assert not cell_applicable(get_config("dbrx-132b"), SHAPES["long_500k"])[0]
    assert cell_applicable(get_config("mamba2-130m"), SHAPES["long_500k"])[0]
    assert cell_applicable(get_config("recurrentgemma-9b"), SHAPES["long_500k"])[0]
    assert cell_applicable(get_config("multihyena-153m"), SHAPES["long_500k"])[0]


def test_cell_count():
    """40 assigned cells = 10 archs x 4 shapes."""
    cells = [(a, s) for a in ASSIGNED for s in SHAPES]
    assert len(cells) == 40
