"""Render EXPERIMENTS.md tables from dry-run JSON artifacts.

  PYTHONPATH=src python -m repro.launch.report experiments/dryrun/dryrun_all_full.json
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def fmt_costs(rows):
    out = ["| arch | shape | Tc (ms) | Tm (ms) | Tcoll (ms) | bottleneck | "
           "useful | roofline |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("kind") != "costs" or r.get("status") != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']:.2f} "
            f"| {r['t_memory_ms']:.2f} | {r['t_collective_ms']:.2f} "
            f"| {r['bottleneck']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.1%} |")
    return "\n".join(out)


def fmt_proofs(rows):
    out = ["| arch | shape | mesh | compile (s) | args/dev (GB) | temp/dev (GB) |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("kind") != "proof" or r.get("status") != "ok":
            continue
        m = r.get("memory", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} "
            f"| {m.get('argument_size', 0)/1e9:.2f} "
            f"| {m.get('temp_size', 0)/1e9:.2f} |")
    return "\n".join(out)


def fmt_skips(rows):
    out = ["| arch | shape | reason |", "|---|---|---|"]
    seen = set()
    for r in rows:
        if r.get("status") == "skipped":
            key = (r["arch"], r["shape"])
            if key in seen:
                continue
            seen.add(key)
            out.append(f"| {r['arch']} | {r['shape']} | {r['reason']} |")
    return "\n".join(out)


def summarize(rows):
    n_ok = sum(1 for r in rows if r.get("status") == "ok")
    n_skip = sum(1 for r in rows if r.get("status") == "skipped")
    n_fail = sum(1 for r in rows if r.get("status") == "FAIL")
    return f"entries: ok={n_ok} skipped={n_skip} failed={n_fail}"


def main():
    rows = []
    for path in sys.argv[1:]:
        rows.extend(json.load(open(path)))
    print("## Summary\n", summarize(rows))
    print("\n## Roofline costs (16x16, per chip)\n")
    print(fmt_costs(rows))
    print("\n## Compile proofs\n")
    print(fmt_proofs(rows))
    print("\n## Skipped cells\n")
    print(fmt_skips(rows))


if __name__ == "__main__":
    main()
