"""Auto-regressive generation engine (paper Sec. 2.2 / 3.4 / 5.4).

Drives prefill + decode for every architecture in the pool. For LCSMs the
engine exposes the paper's three deployment modes:

  * "distilled"   — LaughingHyena recurrent mode: O(d) per token, O(d) state
  * "cached_conv" — Lemma 2.1 baseline: O(t) per token, O(L) kv-product cache
  * (transformers use their native kv cache; SSM/hybrid their native state)

The decode loop is a single jitted step re-invoked from Python (the
benchmark harness also provides a fully-jitted lax.scan loop for timing).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HYENA, ModelConfig
from repro.models.hyena import (hyena_decode_cached_conv, init_hyena_conv_cache,
                                materialize_filters)
from repro.models.layers import NOCTX, ShardCtx, apply_norm, embed_tokens, unembed
from repro.models.model import (decode_step, init_cache, layer_layout, prefill)
from repro.serve.sampling import sample_token


class GenerationEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 4096,
                 ctx: ShardCtx = NOCTX, mode: str = "distilled"):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.ctx = ctx
        self.mode = mode
        self._decode = jax.jit(
            functools.partial(decode_step, cfg=cfg, ctx=ctx),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            functools.partial(prefill, cfg=cfg, max_len=max_len, ctx=ctx))

    def generate(self, key, prompt: jnp.ndarray, n_tokens: int, *,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
                 frontend: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, Dict]:
        """prompt: (B, T) int32 -> (B, n_tokens) generated ids."""
        cache, last_logits = self._prefill(self.params, prompt,
                                           frontend=frontend)
        toks = []
        logits = last_logits
        for i in range(n_tokens):
            key, sub = jax.random.split(key)
            nxt = sample_token(sub, logits, temperature=temperature,
                               top_k=top_k, top_p=top_p)
            toks.append(nxt)
            cache, logits = self._decode(self.params, cache, nxt[:, None])
            logits = logits[:, 0, :]
        return jnp.stack(toks, axis=1), {"cache_bytes": _tree_bytes(cache)}

    # ------------------------------------------------------------------
    def generate_scanned(self, key, prompt: jnp.ndarray, n_tokens: int,
                         frontend: Optional[jnp.ndarray] = None):
        """Fully-jitted greedy generation (used by benchmarks)."""
        cfg, ctx = self.cfg, self.ctx

        @jax.jit
        def run(params, prompt):
            cache, last_logits = prefill(params, prompt, cfg,
                                         max_len=self.max_len, ctx=ctx,
                                         frontend=frontend)
            def body(carry, _):
                cache, logits = carry
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                cache, lg = decode_step(params, cache, nxt[:, None], cfg,
                                        ctx=ctx)
                return (cache, lg[:, 0, :]), nxt

            (_, _), toks = jax.lax.scan(body, (cache, last_logits), None,
                                        length=n_tokens)
            return jnp.moveaxis(toks, 0, 1)

        return run(self.params, prompt)


# ---------------------------------------------------------------------------
# Cached-convolution baseline for LCSMs (Lemma 2.1) — used by benchmarks to
# reproduce the paper's quadratic-vs-recurrent comparison.
# ---------------------------------------------------------------------------
class CachedConvHyenaEngine:
    """Single-layer-stack Hyena decode with the O(t)-per-token kv cache."""

    def __init__(self, params, cfg: ModelConfig, max_len: int = 4096,
                 ctx: ShardCtx = NOCTX):
        assert all(b == HYENA for b in cfg.blocks)
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.ctx = ctx
        n_groups, _ = layer_layout(cfg)
        # pre-materialize filters at max_len for every layer group
        hcfg = cfg.hyena
        self.filters = jax.vmap(
            lambda fp: materialize_filters(fp, max_len, hcfg))(
                params["groups"]["l0"]["mix"]["filter"])

    def init_caches(self, batch: int):
        n_groups, _ = layer_layout(self.cfg)
        one = init_hyena_conv_cache(batch, self.max_len, self.cfg)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), one)

    @functools.partial(jax.jit, static_argnums=(0,))
    def step(self, caches, x_tok, pos):
        """x_tok: (B, 1) int32; caches stacked over groups."""
        cfg, ctx = self.cfg, self.ctx
        params = self.params
        x = embed_tokens(params["embed"], x_tok,
                         dtype=jnp.float32)

        def body(x, inp):
            gp, gc, (h, h0) = inp
            bp = gp["l0"]
            hnorm = apply_norm(bp["norm1"], x, cfg.norm)
            gc, y = hyena_decode_cached_conv(bp["mix"], gc, hnorm, pos, cfg,
                                             (h, h0), ctx=ctx)
            x = x + y
            hnorm = apply_norm(bp["norm2"], x, cfg.norm)
            from repro.models.layers import apply_mlp
            x = x + apply_mlp(bp["mlp"], hnorm, cfg.act, ctx=ctx)
            return x, gc

        x, caches = jax.lax.scan(body, x, (params["groups"], caches,
                                           self.filters))
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["embed"], x, cfg.tie_embeddings, ctx=ctx)
        return caches, logits[:, 0, :]


def _tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))
