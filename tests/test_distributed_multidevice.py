"""Multi-device correctness via subprocess (the main test process keeps a
single device; these spawn a fresh interpreter with 8 forced host devices).

Covers: EP-MoE == dense reference under a real 2x4 mesh; shard_map FFT conv
== plain fft_conv; sharded train step == single-device train step.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str):
    code = textwrap.dedent(body)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stdout + p.stderr


@pytest.mark.slow
def test_moe_ep_matches_dense_on_mesh():
    run_sub("""
    import jax, jax.numpy as jnp
    from repro.configs.base import MoEConfig
    from repro.models.moe import init_moe, moe_dense, moe_expert_parallel
    from repro.distributed.sharding import unzip, SERVE_RULES
    from repro.models.layers import ShardCtx
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    mcfg = MoEConfig(n_experts=8, top_k=2)
    params, _ = unzip(init_moe(jax.random.PRNGKey(0), 32, 64, mcfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    ctx = ShardCtx(mesh=mesh, rules=SERVE_RULES)
    y1, _ = moe_dense(params, x, mcfg)
    with mesh:
        y2, _ = jax.jit(lambda p, x: moe_expert_parallel(
            p, x, mcfg, ctx=ctx, capacity_factor=8.0))(params, x)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    assert err < 1e-4, err
    """)


@pytest.mark.slow
def test_fft_conv_sharded_matches_plain():
    run_sub("""
    import jax, jax.numpy as jnp
    from repro.models.hyena import fft_conv, fft_conv_sharded
    from repro.distributed.sharding import TRAIN_RULES
    from repro.models.layers import ShardCtx
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, rules=TRAIN_RULES)
    u = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 16))
    h = jax.random.normal(jax.random.PRNGKey(1), (4, 64)) * 0.1
    ref = fft_conv(u, jnp.repeat(h, 4, axis=0))
    with mesh:
        out = jax.jit(lambda u, h: fft_conv_sharded(u, h, ctx))(u, h)
    err = float(jnp.max(jnp.abs(ref - out)))
    assert err < 1e-4, err
    # gradient path
    with mesh:
        g = jax.jit(jax.grad(lambda u: fft_conv_sharded(u, h, ctx).sum()))(u)
    assert bool(jnp.all(jnp.isfinite(g)))
    """)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    run_sub("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config, smoke_config
    from repro.distributed.sharding import TRAIN_RULES, tree_shardings, unzip
    from repro.models.model import init_params
    from repro.train.train_step import init_opt, make_train_step
    cfg = smoke_config(get_config("llama3.2-3b")).replace(
        vocab=64, d_model=32, d_ff=64, n_heads=4, n_kv_heads=2, head_dim=8,
        n_layers=2, dtype="float32")
    ptree = init_params(jax.random.PRNGKey(0), cfg)
    params, axes = unzip(ptree)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0, 64)}
    # single device
    s1 = make_train_step(cfg, None, remat="none")
    p1, o1, m1 = jax.jit(s1)(params, init_opt(params), batch, jnp.asarray(0))
    # 2x4 mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sh = tree_shardings(params, axes, TRAIN_RULES, mesh)
    pm = jax.device_put(params, sh)
    s2 = make_train_step(cfg, mesh, remat="none")
    with mesh:
        p2, o2, m2 = jax.jit(s2)(pm, init_opt(pm), batch, jnp.asarray(0))
    d = float(abs(m1["loss"] - m2["loss"]))
    assert d < 1e-3, d
    mx = max(float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert mx < 1e-3, mx
    """)
