"""Fig 5.3: throughput scaling in prompt length T (fixed batch).

LaughingHyena prefills via convolutions (O~(T)); the Transformer's attention
prefill is O(T^2).
"""
import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from benchmarks.models import build, hyena_cfg, transformer_cfg
from repro.serve.engine import GenerationEngine

K_GEN, BATCH = 32, 8


def main(out):
    tcfg, hcfg = transformer_cfg(), hyena_cfg()
    tparams = build(tcfg)
    hparams = build(hcfg, distill=True)
    for T in (128, 512, 2048):
        for name, cfg, params in (("transformer", tcfg, tparams),
                                  ("laughinghyena", hcfg, hparams)):
            eng = GenerationEngine(params, cfg, max_len=T + K_GEN)
            prompt = jnp.ones((BATCH, T), jnp.int32)
            dt = timeit(lambda: eng.generate_scanned(jax.random.PRNGKey(0),
                                                     prompt, K_GEN),
                        warmup=1, iters=3)
            out(row(f"fig5.3/{name}/T{T}", dt * 1e6,
                    f"tok_s={BATCH*K_GEN/dt:.0f}"))
