"""Sec. 3.4 pre-filling strategies + App. A transfer-function machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (companion_from_tf, companion_step, eval_filter,
                        init_modal, poly_from_roots, prefill_fft,
                        prefill_recurrent, prefill_scan, prefill_vandermonde,
                        transfer_eval_fft)
from repro.core.transfer import get_tf_from_ss, impulse_from_tf, tf_from_modal


@pytest.fixture(scope="module")
def system():
    return init_modal(jax.random.PRNGKey(0), (3,), 5, r_minmax=(0.4, 0.9))


@pytest.mark.slow
def test_prefill_strategies_agree(system):
    u = jax.random.normal(jax.random.PRNGKey(1), (3, 128))
    xr = prefill_recurrent(system, u)
    scale = float(jnp.max(jnp.abs(xr))) + 1e-9
    for fn in (prefill_scan, prefill_vandermonde, prefill_fft):
        x = fn(system, u)
        err = float(jnp.max(jnp.abs(x - xr))) / scale
        assert err < 1e-2, (fn.__name__, err)


def test_prefill_then_step_matches_full_conv(system):
    """State from prefill + one modal step == direct convolution output."""
    from repro.core.modal import modal_step
    T = 96
    u = jax.random.normal(jax.random.PRNGKey(2), (3, T + 1))
    h = eval_filter(system, T + 1)
    # y_T by direct convolution: sum_j h[T-j] u_j
    yT = jnp.einsum("cj,cj->c", h[:, ::-1], u)
    xT = prefill_recurrent(system, u[:, :T])
    y, _, _ = modal_step(system, jnp.real(xT), jnp.imag(xT), u[:, T])
    np.testing.assert_allclose(np.asarray(y), np.asarray(yT), atol=1e-3)


def test_poly_from_roots():
    r = jnp.asarray([1.0 + 0j, 2.0 + 0j, 3.0 + 0j])
    c = poly_from_roots(r)
    np.testing.assert_allclose(np.asarray(jnp.real(c)), [1, -6, 11, -6],
                               atol=1e-5)


def test_companion_impulse_matches_modal(system):
    one = jax.tree.map(lambda x: x[0], system)
    a, b = tf_from_modal(one.poles(), one.residues(), one.h0)
    assert float(jnp.max(jnp.abs(jnp.imag(a)))) < 1e-3   # conj completion
    A, B, C, h0 = companion_from_tf(jnp.real(a), jnp.real(b), one.h0)
    alpha = jnp.real(a)[1:]
    x = jnp.zeros(alpha.shape[-1])
    out = []
    for t in range(48):
        x, y = companion_step(x, 1.0 if t == 0 else 0.0, alpha, jnp.real(b), h0)
        out.append(float(y))
    h = np.asarray(eval_filter(one, 48))
    np.testing.assert_allclose(np.array(out), h, atol=2e-2)


def test_transfer_eval_fft_matches_time_domain(system):
    """Lemma A.6: FFT evaluation of H == DFT of the impulse response, up to
    the rho^L truncation correction (App. A.4)."""
    one = jax.tree.map(lambda x: x[0], system)
    L = 512
    a, b = tf_from_modal(one.poles(), one.residues(), one.h0)
    H = transfer_eval_fft(a, b, one.h0[None], L)[0]
    h = eval_filter(one, L)
    Hd = jnp.fft.fft(h, axis=-1)
    err = float(jnp.max(jnp.abs(H - Hd))) / float(jnp.max(jnp.abs(Hd)))
    assert err < 1e-2, err


def test_get_tf_from_ss_roundtrip():
    """Listing 1: dense SSM -> (a, b) -> impulse matches the dense impulse."""
    key = jax.random.PRNGKey(3)
    d = 4
    A = 0.5 * jax.random.normal(key, (d, d)) / np.sqrt(d)
    B = jax.random.normal(jax.random.PRNGKey(4), (d,))
    C = jax.random.normal(jax.random.PRNGKey(5), (d,))
    h0 = jnp.asarray(0.3)
    a, beta = get_tf_from_ss(A, B, C, h0)
    # impulse of dense system
    imp = [float(h0)]
    x = B
    for _ in range(31):
        imp.append(float(C @ x))
        x = A @ x
    h = impulse_from_tf(jnp.real(a), jnp.real(beta), h0[None], 32)[0]
    np.testing.assert_allclose(np.asarray(h), np.array(imp), atol=1e-3)
