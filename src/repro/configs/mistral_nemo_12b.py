"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407].

Dense decoder, 40L d_model=5120 32H (GQA kv=8) head_dim=128 d_ff=14336
vocab=131072, 128k context, SwiGLU, RoPE theta=1e6.
"""
from repro.configs.base import ATTN, ModelConfig, register


@register
def mistral_nemo_12b() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=131072,
        act="swiglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        pattern=(ATTN,),
        max_seq=131072,
    )
