"""Logical-axis sharding.

Every parameter is created as a `Param(value, axes)` where `axes` names the
logical axis of each dimension (or None). A `ShardingRules` maps logical axes
to an ordered list of candidate mesh axes; `resolve_spec` assigns each dim the
first candidate mesh axis that (a) is not already used by another dim of the
same array and (b) evenly divides the dim. This gives divisibility-safe
FSDP+TP specs for every architecture without per-arch special cases.

Logical axes used across the model zoo:
  batch    — per-example axis of activations
  seq      — sequence axis (sequence parallelism optional)
  embed    — d_model rows of weight matrices (FSDP shard axis in training)
  mlp      — d_ff / intermediate columns (TP)
  heads    — attention/ssd head axis (TP)
  kv_heads — kv head axis (TP when divisible, else replicated)
  qkv      — fused q/k/v output axis (TP)
  vocab    — vocabulary axis (TP)
  expert   — MoE expert axis (EP)
  state    — SSM/LRU recurrent-state axis
  conv     — short-conv tap axis (never sharded)
  filters  — hyena filter-head axis
  slots    — serving slot-pool rows (one request per row; data-parallel)
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Param(NamedTuple):
    value: Any                       # jnp.ndarray (or ShapeDtypeStruct)
    axes: Tuple[Optional[str], ...]  # logical axis name per dim


def is_param(x) -> bool:
    return isinstance(x, Param)


def unzip(tree):
    """Split a tree of Params into (values_tree, axes_tree)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def zip_specs(values, axes):
    return jax.tree.map(Param, values, axes)


class ShardingRules(NamedTuple):
    """logical axis -> ordered candidates of mesh axes (each a str or tuple)."""
    rules: Dict[str, Sequence[Any]]

    def candidates(self, logical: Optional[str]) -> Sequence[Any]:
        if logical is None:
            return ()
        return self.rules.get(logical, ())


def _mesh_axis_size(mesh_shape: Dict[str, int], axis) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh_shape.get(a, 1)
        return n
    return mesh_shape.get(axis, 1)


def resolve_spec(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
                 rules: ShardingRules, mesh_shape: Dict[str, int]) -> P:
    """Greedy divisibility-safe assignment of mesh axes to dims."""
    used: set = set()
    out = []
    for dim, logical in zip(shape, axes):
        assigned = None
        for cand in rules.candidates(logical):
            flat = cand if isinstance(cand, tuple) else (cand,)
            if any(a in used or a not in mesh_shape for a in flat):
                continue
            if _mesh_axis_size(mesh_shape, cand) <= 1:
                continue
            if dim % _mesh_axis_size(mesh_shape, cand) != 0:
                continue
            assigned = cand
            used.update(flat)
            break
        out.append(assigned)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_specs(values, axes, rules: ShardingRules, mesh: Mesh):
    """PartitionSpec tree for a (values, axes) pair of trees."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(v, a):
        return resolve_spec(tuple(v.shape), tuple(a), rules, mesh_shape)

    return jax.tree.map(one, values, axes)


def tree_shardings(values, axes, rules: ShardingRules, mesh: Mesh):
    specs = tree_specs(values, axes, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------
# Training: FSDP over ('pod','data') on the embed axis + TP over 'model'.
TRAIN_RULES = ShardingRules(rules={
    "batch": [("pod", "data"), "data"],
    "seq": [],
    "embed": [("pod", "data"), "data"],   # FSDP shard axis
    "mlp": ["model"],
    "heads": ["model"],
    "kv_heads": ["model"],
    "qkv": ["model"],
    "vocab": ["model"],
    "expert": ["model"],
    "state": [],
    "kv_seq": [],
    "qseq": ["model"],                    # context-parallel attention q rows
    "filters": [],
    "act_embed": [],                      # activations keep d_model replicated
})

# Pure FSDP ("zero-3"): every device is a data-parallel worker; parameters
# shard their embed (d_model) axis across the ENTIRE mesh and are all-gathered
# at use. No tensor-parallel activation collectives at all — the right mapping
# for models whose per-device batch stays >= 1 at full mesh (3B-12B dense).
FSDP_RULES = ShardingRules(rules={
    "batch": [("pod", "data", "model"), ("data", "model"), ("pod", "data"),
              "data"],
    "seq": [],
    "embed": [("pod", "data", "model"), ("data", "model")],
    "mlp": [],
    "heads": [],
    "kv_heads": [],
    "qkv": [],
    "vocab": [],
    "expert": [],
    "state": [],
    "kv_seq": [],
    "qseq": [],
    "filters": [],
    "act_embed": [],
})

# Serving: pure TP (params replicated across data; batch over data).
SERVE_RULES = ShardingRules(rules={
    "batch": [("pod", "data"), "data"],
    "seq": [],
    "embed": [],
    "mlp": ["model"],
    "heads": ["model"],
    "kv_heads": ["model"],
    "qkv": ["model"],
    "vocab": ["model"],
    "expert": ["model"],
    # decode caches: shard the cache sequence axis over the TP axis
    # (flash-decoding style partial softmax; works for any kv-head count),
    # recurrent states shard their state axis when divisible.
    "state": ["model"],
    "kv_seq": ["model"],
    "qseq": ["model"],
    "filters": [],
    "act_embed": [],
})


# Serving slot pool: the per-request row axis shards over the data axis and
# NOTHING else does — each slot's recurrence is independent, so a row-sharded
# pool decodes with zero cross-device communication. Model dims, the stacked
# layer axis, and positions within a row stay local to each shard.
SLOT_RULES = ShardingRules(rules={"slots": [("pod", "data"), "data"]})


def slot_axes(axes_tree):
    """Map a cache axes-tree (from `unzip(init_cache(...))`) to slot-pool
    logical axes: the per-request 'batch' dim becomes 'slots'; every other
    dim is replicated. Feed the result to `tree_specs`/`tree_shardings` with
    SLOT_RULES to resolve the pool's shardings on a data mesh."""
    def one(a):
        return tuple("slots" if x == "batch" else None for x in a)
    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def constrain(x, axes: Tuple[Optional[str], ...], rules: ShardingRules,
              mesh: Optional[Mesh]):
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    if mesh is None or mesh.empty:
        return x
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = resolve_spec(tuple(x.shape), tuple(axes), rules, mesh_shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def count_bytes(values) -> int:
    leaves = jax.tree.leaves(values)
    return int(sum(np.prod(l.shape) * l.dtype.itemsize for l in leaves))


def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map with unchecked replication across jax versions
    (jax>=0.8: jax.shard_map(check_vma=...); older: experimental check_rep)."""
    try:
        from jax import shard_map as _sm
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    except (ImportError, TypeError):  # pragma: no cover
        from jax.experimental.shard_map import shard_map as _sm
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
