"""Public wrapper: fused modal-SSM decode step."""
from __future__ import annotations

import jax

from repro.kernels.ssm_decode.ref import ssm_decode_ref
from repro.kernels.ssm_decode.ssm_decode import ssm_decode_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssm_decode(x_re, x_im, u, log_a, theta, R_re, R_im, h0, *,
               use_pallas: bool = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return ssm_decode_pallas(x_re, x_im, u, log_a, theta, R_re, R_im, h0,
                                 interpret=not _on_tpu())
    return ssm_decode_ref(x_re, x_im, u, log_a, theta, R_re, R_im, h0)
