from repro.serve.engine import GenerationEngine  # noqa: F401
from repro.serve.sampling import sample_token    # noqa: F401
