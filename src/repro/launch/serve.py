"""Serving launcher: batched auto-regressive generation.

  PYTHONPATH=src python -m repro.launch.serve --arch multihyena-153m --smoke \
      --batch 8 --prompt-len 64 --gen 32 [--ckpt /tmp/run1] [--distill]

For LCSM archs, --distill runs LaughingHyena distillation before serving
(recurrent O(d) decode); without it the model still serves via the distilled
slot's random init (useless outputs) — so in practice always pass --distill
or a --ckpt of a trained+distilled model.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.core.distill import distill_model
from repro.distributed.sharding import unzip
from repro.models.model import init_params
from repro.serve.engine import GenerationEngine
from repro.train.checkpoint import Checkpointer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--distill", action="store_true")
    ap.add_argument("--distill-order", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = unzip(init_params(key, cfg))
    if args.ckpt:
        ck = Checkpointer(args.ckpt)
        (params, _), step = ck.restore((params, None))
        print(f"[serve] restored step {step}")
    if args.distill and cfg.hyena is not None:
        t0 = time.time()
        params, errs = distill_model(params, cfg, d=args.distill_order)
        import numpy as np
        worst = max(float(jnp.max(e)) for e in errs.values())
        print(f"[serve] distilled filters to order {args.distill_order} in "
              f"{time.time()-t0:.1f}s (worst rel l2 err {worst:.3e})")

    engine = GenerationEngine(params, cfg,
                              max_len=args.prompt_len + args.gen)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    toks, info = engine.generate(key, prompt, args.gen,
                                 temperature=args.temperature)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s), cache={info['cache_bytes']/1e6:.2f}MB")
    print(toks[0][:16])


if __name__ == "__main__":
    main()
