# Tiered test entry points (see pytest.ini: `slow` tests are deselected by
# default, so `test-fast` is the tier-1 suite the driver runs).
PY := PYTHONPATH=src python

.PHONY: test-fast test-all test-slow bench bench-serve

test-fast:
	$(PY) -m pytest -x -q

test-all:
	$(PY) -m pytest -q -m "slow or not slow"

test-slow:
	$(PY) -m pytest -q -m slow

bench:
	$(PY) -m benchmarks.run

# serving perf trajectory: tok/s, latency/TTFT percentiles, and prefill
# compile counts per mode, written to BENCH_serve.json for cross-PR tracking
bench-serve:
	$(PY) -m benchmarks.run --only serve_stream --json BENCH_serve.json
