"""Engine checkpoint/restore: snapshot a live ContinuousBatchingEngine to
host and resume it bit-exactly in a fresh process.

What makes exact resume cheap here is the same property that makes slot
serving cheap: a request's entire decode state is a fixed-size cache row
plus a handful of per-slot metadata scalars, and the PRNG stream is
position-indexed — fold_in(engine_key, rid) at stream index tok_idx — so
"where every request's randomness is" is fully captured by (rid, tok_idx),
both of which are in the snapshot. Restoring the pooled cache, the device
metadata vectors, and the host bookkeeping therefore continues every
resident request token-for-token as if the process had never died.

Checkpoint format (pickle, `format: 2`): a dict of
  * engine shape/compat: mode, n_slots, max_len, cache_kind
  * mesh: None for a single-device engine, else the slot-pool mesh layout
    (axis names, shape, shard count, per-slot shard ownership) — restore
    refuses a layout mismatch instead of silently resharding, because the
    device buffers in the snapshot are laid out per shard
  * device state (device_get to numpy): cache, draft_cache, meta vectors
    (_temps/_top_ks/_top_ps/_last/_slot_keys/_tok_idx/_spec_len), spec_win
  * host bookkeeping: slots, queue, finished (pickled Request objects —
    object identity between slots/queue entries is preserved), active,
    tick, next_rid, t_admit, stats, resilience counters, buckets_used

Format 1 (pre-sharding) snapshots carry no mesh entry; they still load,
but only into a single-device engine.

Not captured: compiled executables (the restored engine re-warms or
recompiles on demand) and the SlotSpecController's acceptance EMAs (windows
re-adapt from defaults; greedy token-exactness is unaffected because draw
keys are position-indexed, not path-dependent). An in-flight chunked
prefill is requeued whole — its request restarts prefill from scratch.
"""
from __future__ import annotations

import pickle
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_META_KEYS = ("_temps", "_top_ks", "_top_ps", "_last", "_slot_keys",
              "_tok_idx", "_spec_len")
FORMAT = 2


def _mesh_desc(engine) -> Optional[Dict[str, Any]]:
    """Canonical description of the engine's slot-pool layout (None when
    single-device). Compared verbatim at restore: two engines with equal
    descriptions place every slot row on the same shard."""
    if getattr(engine, "mesh", None) is None:
        return None
    mesh = engine.mesh
    return {
        "axis_names": [str(a) for a in mesh.axis_names],
        "shape": [int(s) for s in mesh.devices.shape],
        "n_shards": int(engine._n_shards),
        "slot_shard": [int(engine._shard_of(b))
                       for b in range(engine.n_slots)],
    }


def save_engine(engine, path: Optional[str] = None) -> Dict[str, Any]:
    """Snapshot `engine` to a host-side dict (and pickle it to `path` when
    given). The in-flight overlapped tick is retired first and an in-flight
    chunked prefill is requeued, so the snapshot is a consistent
    between-ticks view; the engine remains usable afterwards."""
    from repro.serve.scheduler import QUEUED

    engine._retire(engine._pending)
    engine._pending = None
    if engine._chunk_state is not None:
        st = engine._chunk_state
        engine._chunk_state = None
        engine.slots[st["slot"]] = None
        req = st["req"]
        req.status = QUEUED
        req.slot = -1
        engine.queue.appendleft(req)
    state: Dict[str, Any] = {
        "format": FORMAT,
        "mode": engine.mode,
        "n_slots": engine.n_slots,
        "max_len": engine.max_len,
        "cache_kind": engine._cache_kind,
        "mesh": _mesh_desc(engine),
        "cache": jax.device_get(engine.cache),
        "draft_cache": (None if engine.draft_cache is None
                        else jax.device_get(engine.draft_cache)),
        "meta": {k: np.asarray(getattr(engine, k)) for k in _META_KEYS},
        "spec_win": engine._spec_win.copy(),
        "active": engine.active.copy(),
        "slots": list(engine.slots),
        "queue": list(engine.queue),
        "finished": list(engine.finished),
        "tick": engine._tick,
        "next_rid": engine._next_rid,
        "t_admit": engine.t_admit,
        "stats": dict(engine.stats),
        "resilience": engine.resilience.snapshot(),
        "buckets_used": sorted(engine._buckets_used),
    }
    engine.resilience.bump("checkpoint_saves")
    engine._record_event("checkpoint_save", path=path)
    if path is not None:
        with open(path, "wb") as f:
            pickle.dump(state, f)
    return state


def load_checkpoint(path: str) -> Dict[str, Any]:
    with open(path, "rb") as f:
        return pickle.load(f)


def restore_engine(engine, state) -> None:
    """Load a `save_engine` snapshot into a freshly constructed engine (same
    arch/params and construction shape). Restoring a snapshot taken after a
    mode-ladder demotion (distilled→cached_conv→epoch) into a higher-mode
    engine replays the demotion first. Resumes bit-exactly: resident slots continue from their
    exact cache rows, stream counters, and last tokens."""
    if isinstance(state, str):
        state = load_checkpoint(state)
    fmt = state.get("format")
    if fmt not in (1, FORMAT):
        raise ValueError(f"unknown checkpoint format {fmt!r}")
    if (state["n_slots"] != engine.n_slots
            or state["max_len"] != engine.max_len):
        raise ValueError(
            f"checkpoint shape (n_slots={state['n_slots']}, "
            f"max_len={state['max_len']}) does not match the engine "
            f"(n_slots={engine.n_slots}, max_len={engine.max_len})")
    here = _mesh_desc(engine)
    if fmt == 1:
        if here is not None:
            raise ValueError(
                "format-1 checkpoint carries no mesh metadata and cannot be "
                "restored into a sharded engine "
                f"(engine slot-pool layout: {here})")
    else:
        saved = state.get("mesh")
        if saved != here:
            raise ValueError(
                f"checkpoint slot-pool mesh layout {saved} does not match "
                f"the engine's {here} — rebuild the engine with the same "
                f"mesh (or restore single-device from a single-device "
                f"snapshot)")
    if state["mode"] != engine.mode:
        from repro.serve.scheduler import MODE_LADDER
        saved_rung = (MODE_LADDER.index(state["mode"])
                      if state["mode"] in MODE_LADDER else -1)
        here_rung = MODE_LADDER.index(engine.mode)
        if saved_rung > here_rung:
            # snapshot was taken after the engine walked down the ladder
            # (fault quarantine or drift alarm): replay the demotion so the
            # restored pool kind matches the saved cache buffers
            engine._demote_engine(state["mode"])
        else:
            raise ValueError(
                f"checkpoint mode {state['mode']!r} does not match engine "
                f"mode {engine.mode!r} (a snapshot only restores into the "
                f"same mode or one higher on the ladder {MODE_LADDER})")
    engine._pending = None
    engine._chunk_state = None
    engine.cache = engine._put_pool(state["cache"], engine._cache_sh)
    if state["draft_cache"] is not None:
        if engine.draft_cache is None:
            raise ValueError("checkpoint has a draft pool but the engine "
                             "was built without one (spec config mismatch)")
        engine.draft_cache = engine._put_pool(state["draft_cache"],
                                              engine._draft_sh)
    for k in _META_KEYS:
        setattr(engine, k, engine._put_slot_vec(state["meta"][k]))
    engine._spec_win[:] = state["spec_win"]
    engine._spec_win_dev[:] = state["spec_win"]
    engine.active[:] = state["active"]
    engine.slots = list(state["slots"])
    from collections import deque
    engine.queue = deque(state["queue"])
    engine.finished = list(state["finished"])
    # the restored engine's dispatch counter starts fresh and no pending
    # exists, so the saved process's staleness marks must not carry over
    for r in list(engine.slots) + list(engine.queue):
        if r is not None:
            r.admit_seq = -1
            r.retry_at = 0
    engine._tick = int(state["tick"])
    engine._next_rid = int(state["next_rid"])
    engine.t_admit = float(state["t_admit"])
    engine.stats.update(state["stats"])
    for k, v in state["resilience"].items():
        engine.resilience.bump(k, v)
    engine._buckets_used.update(state["buckets_used"])
    engine._any_deadline = engine._any_deadline or any(
        r is not None and r.deadline_s is not None
        for r in list(engine.slots) + list(engine.queue))
    engine.resilience.bump("checkpoint_restores")
    engine._record_event("checkpoint_restore")
