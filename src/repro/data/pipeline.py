"""Data pipeline.

Two sources:
  SyntheticLM   — deterministic synthetic LM streams (Zipf-ish unigram mix +
                  copy/recall structure so models have learnable signal).
                  Step-indexed: batch(step) is a pure function of (seed, step)
                  so a restarted job resumes mid-epoch with no state to
                  persist beyond the step counter (fault-tolerance property).
  MemmapTokens  — memory-mapped pre-tokenized corpus (the production path):
                  each data-parallel host reads only its strided window.

Batches are placed host-locally and assembled into a global jax.Array with
make_array_from_process_local_data when a mesh is provided — the multi-host
pattern; on a single host it degrades to device_put with the batch sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> np.ndarray:
        """(B, S+1) int32 tokens, pure function of (seed, step)."""
        rng = np.random.default_rng(np.int64(self.seed) * 1_000_003 + step)
        B, S = self.global_batch, self.seq_len + 1
        # zipf-ish unigram distribution for realistic logit scales
        ranks = np.arange(1, self.vocab + 1)
        probs = 1.0 / ranks ** 1.1
        probs /= probs.sum()
        toks = rng.choice(self.vocab, size=(B, S), p=probs).astype(np.int32)
        # inject copy structure: second half repeats a shifted window of the
        # first half for 25% of rows — gives recurrent models signal to learn
        n = B // 4
        if n and S >= 8:
            half = S // 2
            toks[:n, half:half * 2] = toks[:n, :half]
        return toks


@dataclasses.dataclass
class MemmapTokens:
    """Flat .bin of uint16/uint32 token ids, strided per data-parallel rank."""
    path: str
    vocab: int
    seq_len: int
    global_batch: int
    dtype: str = "uint16"

    def __post_init__(self):
        self._arr = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._ntok = len(self._arr)

    def batch(self, step: int) -> np.ndarray:
        B, S = self.global_batch, self.seq_len + 1
        span = B * S
        start = (step * span) % max(self._ntok - span, 1)
        flat = np.asarray(self._arr[start:start + span], dtype=np.int32)
        return flat.reshape(B, S) % self.vocab


def place_batch(tokens: np.ndarray, mesh: Optional[Mesh]) -> Dict:
    """Host batch -> global jax.Array sharded over the batch axes."""
    if mesh is None or mesh.empty:
        return {"tokens": jnp.asarray(tokens)}
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    sharding = NamedSharding(mesh, P(tuple(axes) if len(axes) > 1 else axes[0]))
    if jax.process_count() > 1:  # pragma: no cover (multi-host only)
        arr = jax.make_array_from_process_local_data(sharding, tokens)
    else:
        arr = jax.device_put(tokens, sharding)
    return {"tokens": arr}


def make_batches(source, mesh: Optional[Mesh] = None, start_step: int = 0
                 ) -> Iterator[Dict]:
    step = start_step
    while True:
        yield place_batch(source.batch(step), mesh)
        step += 1
