"""Token sampling: greedy, temperature, top-k, top-p (nucleus).

`sample_token` takes python-scalar params shared across the batch (one
request replicated, or homogeneous batches). `sample_token_slots` takes
per-row (B,) parameter vectors — the continuous-batching engine serves
requests with heterogeneous sampling params in one batched step.

PRNG key streams: `sample_token_slots` accepts either one key (2,) that is
split across rows (legacy behavior), or per-row keys (B, 2). The serving
engine derives per-row keys from a per-(slot, token-index) key tree (see
serve/README.md "Key tree") so the speculative and non-speculative decode
paths consume identical key streams per emitted-token position — that is
what `filter_logits` is factored out for: the speculative verifier applies
the exact same temperature/top-k/top-p filtering to target and draft
distributions before rejection sampling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(key, logits, *, temperature: float = 1.0, top_k: int = 0,
                 top_p: float = 1.0):
    """logits: (B, V) -> (B,) int32. One pipeline: scalar params broadcast
    into the per-slot implementation so the two paths can never diverge."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    B = logits.shape[0]
    return sample_token_slots(
        key, logits,
        temperature=jnp.full((B,), temperature, jnp.float32),
        top_k=jnp.full((B,), top_k, jnp.int32),
        top_p=jnp.full((B,), top_p, jnp.float32))


def filter_logits(logits, *, temperature, top_k, top_p):
    """Temperature-scaled + top-k/top-p-filtered logits.

    logits: (B, V); temperature/top_k/top_p: (B,). Returns (B, V) float32
    with -inf outside each row's sampling support — softmax of the result is
    the exact distribution `sample_token_slots` draws from (rows with
    temperature <= 0 are greedy there and ignore this). Shared by the
    per-slot sampler and the speculative-decoding verifier so the rejection
    test compares the same filtered distributions the sampler uses.
    """
    B, V = logits.shape
    temperature = jnp.asarray(temperature, jnp.float32)
    top_k = jnp.asarray(top_k, jnp.int32)
    top_p = jnp.asarray(top_p, jnp.float32)
    lg = logits.astype(jnp.float32) / jnp.clip(temperature, 1e-6)[:, None]
    # per-row top-k: the k-th largest value is the row's cutoff (k<=0 -> V)
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V)
    srt = jnp.sort(lg, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(srt, (k_eff - 1)[:, None], axis=-1)
    lg = jnp.where(lg < kth, -jnp.inf, lg)
    # per-row top-p over the filtered logits (mirrors sample_token)
    srt2 = jnp.sort(lg, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(srt2, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.sum(cum < top_p[:, None], axis=-1)
    cutoff = jnp.take_along_axis(srt2, jnp.clip(cutoff_idx, 0, V - 1)[:, None],
                                 axis=-1)
    lg = jnp.where((top_p[:, None] < 1.0) & (lg < cutoff), -jnp.inf, lg)
    return lg


def sample_token_slots(key, logits, *, temperature, top_k, top_p):
    """Per-slot sampling. logits: (B, V); temperature/top_k/top_p: (B,).

    Rows with temperature <= 0 are greedy; top_k <= 0 / top_p >= 1 disable
    the respective filter for that row. `key` is either a single PRNG key
    (2,) split across rows, or per-row keys (B, 2) — the serving engine
    passes per-row keys from its per-(slot, token-index) key tree so one
    slot's draw never perturbs another's and the speculative path can replay
    the identical stream.
    """
    B, V = logits.shape
    temperature = jnp.asarray(temperature, jnp.float32)
    # NaN-proof greedy: argmax over the raw logits with NaN masked to -inf,
    # so a poisoned row yields a deterministic token (index 0 when the whole
    # row is non-finite) instead of NaN-comparison-dependent junk
    raw = jnp.where(jnp.isnan(logits), -jnp.inf, logits).astype(jnp.float32)
    greedy = jnp.argmax(raw, axis=-1).astype(jnp.int32)

    def sample(_):
        lg = filter_logits(logits, temperature=temperature, top_k=top_k,
                           top_p=top_p)
        # degenerate rows — filtering left no finite support (e.g. top_p=0)
        # or NaN logits leaked through — would softmax to NaN probabilities;
        # fall back to argmax over the raw logits for those rows
        bad = (~jnp.any(jnp.isfinite(lg), axis=-1)
               | jnp.any(jnp.isnan(lg), axis=-1))
        keys = key if key.ndim == 2 else jax.random.split(key, B)
        lg_safe = jnp.where(bad[:, None], 0.0, lg)
        sampled = jax.vmap(jax.random.categorical)(keys,
                                                   lg_safe).astype(jnp.int32)
        sampled = jnp.where(bad, greedy, sampled)
        return jnp.where(temperature <= 0.0, greedy, sampled)

    # all-greedy fast path: skips the sort-based top-k/top-p filter (the
    # serving hot loop calls this every tick / every draft-scan step)
    return jax.lax.cond(jnp.all(temperature <= 0.0), lambda _: greedy,
                        sample, None)
