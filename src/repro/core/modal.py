"""Modal canonical form (paper Sec. 3.2, App. B.1).

A distilled filter is parameterized by d poles and residues:

    h_hat_t = Re[ sum_n R_n * lam_n^(t-1) ],  t >= 1;   h_hat_0 = h0.

Poles in polar form lam_n = exp(log_a_n) * exp(i theta_n) (unconstrained —
App. B.1 point 2: no stability constraint during distillation), residues in
cartesian form, B = 1 (App. B.1 point 1). All arrays carry a leading "filter"
batch shape (...,) so a whole model's filters distill in one jit.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ModalSSM(NamedTuple):
    """Pytree of modal parameters; leading dims = filter batch, last = d."""
    log_a: jnp.ndarray      # (..., d) log |lam|
    theta: jnp.ndarray      # (..., d) phase
    R_re: jnp.ndarray       # (..., d)
    R_im: jnp.ndarray       # (..., d)
    h0: jnp.ndarray         # (...,)  passthrough

    @property
    def order(self) -> int:
        return self.log_a.shape[-1]

    def poles(self) -> jnp.ndarray:
        return jnp.exp(self.log_a + 1j * self.theta)

    def residues(self) -> jnp.ndarray:
        return self.R_re + 1j * self.R_im


def init_modal(key, batch_shape: Tuple[int, ...], d: int,
               r_minmax=(0.7, 0.999)) -> ModalSSM:
    k1, k2, k3 = jax.random.split(key, 3)
    mag = jax.random.uniform(k1, batch_shape + (d,), minval=r_minmax[0],
                             maxval=r_minmax[1])
    return ModalSSM(
        log_a=jnp.log(mag),
        theta=jax.random.uniform(k2, batch_shape + (d,), maxval=np.pi),
        R_re=jax.random.normal(k3, batch_shape + (d,)) / d,
        R_im=jnp.zeros(batch_shape + (d,)),
        h0=jnp.zeros(batch_shape),
    )


def eval_filter(ssm: ModalSSM, L: int) -> jnp.ndarray:
    """Materialize h_hat (.., L) including index 0. O(dL) (Lemma 3.1).

    h_hat[0] = h0; h_hat[t] = Re sum_n R_n lam_n^(t-1) = sum_n a^(t-1) *
    [R_re cos(theta (t-1)) - R_im sin(theta (t-1))] (Sec. 3.2).
    """
    t = jnp.arange(L - 1, dtype=jnp.float32)                    # exponent t-1
    mag = jnp.exp(ssm.log_a[..., None] * t)                     # (.., d, L-1)
    ang = ssm.theta[..., None] * t
    tail = jnp.einsum("...d,...dl->...l", ssm.R_re, mag * jnp.cos(ang)) \
        - jnp.einsum("...d,...dl->...l", ssm.R_im, mag * jnp.sin(ang))
    return jnp.concatenate([ssm.h0[..., None], tail], axis=-1)


def modal_step(ssm: ModalSSM, x_re, x_im, u):
    """One recurrent step (Prop. 3.3, paper output convention).

    y_t = Re[R . x_t] + h0 u_t ;  x_{t+1} = lam x_t + 1 u_t.
    x_re/x_im: (.., d); u: (..,). Returns (y, x_re', x_im').
    """
    y = jnp.sum(ssm.R_re * x_re - ssm.R_im * x_im, axis=-1) + ssm.h0 * u
    lr = jnp.exp(ssm.log_a) * jnp.cos(ssm.theta)
    li = jnp.exp(ssm.log_a) * jnp.sin(ssm.theta)
    nxr = lr * x_re - li * x_im + u[..., None]
    nxi = lr * x_im + li * x_re
    return y, nxr, nxi


def effective_order(ssm: ModalSSM, tol: float = 1e-4) -> jnp.ndarray:
    """Number of modes whose worst-case contribution |R|/(1-|lam|) > tol."""
    a = jnp.exp(ssm.log_a)
    infl = jnp.abs(ssm.residues()) / jnp.clip(1.0 - a, 1e-6)
    return jnp.sum(infl > tol, axis=-1)
