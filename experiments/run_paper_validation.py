"""Paper-claim validation runs (EXPERIMENTS.md 'Reproduction' section).

  PYTHONPATH=src python experiments/run_paper_validation.py

1. Table 5.1 proxy  — MultiHyena (8 tied filter heads) vs per-channel Hyena
                      pretraining loss at matched size, 300 steps synthetic.
2. Fig 5.2          — distillation error vs order on the TRAINED model's
                      filters + Hankel spectrum decay.
3. Fig 5.1 / T 5.2  — relative logit error of distilled vs base model at
                      orders {4, 8, 16, 32} (the paper's quality cliff at
                      order < 16 should reproduce).
4. Sec 3.4          — pre-filling strategy agreement (numerical).
"""
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HYENA, HyenaConfig, ModelConfig
from repro.core.distill import distill_filters, distill_model
from repro.core.hankel import hankel_singular_values
from repro.core.modal import eval_filter
from repro.data.pipeline import SyntheticLM
from repro.distributed.sharding import unzip
from repro.models.hyena import materialize_filters
from repro.models.model import decode_step, forward, init_params, prefill
from repro.train.train_step import init_opt, make_train_step

RESULTS = {}


def make_cfg(heads):
    return ModelConfig(
        name=f"val-hyena-m{heads}", family="lcsm", n_layers=4, d_model=128,
        n_heads=8, n_kv_heads=8, head_dim=16, d_ff=512, vocab=512, act="gelu",
        norm="layernorm", pattern=(HYENA,),
        hyena=HyenaConfig(n_filter_heads=heads, filter_order=32,
                          filter_emb=17, distill_order=16),
        tie_embeddings=True, max_seq=65536, dtype="float32")


def train_model(cfg, steps=300, seed=0):
    params, _ = unzip(init_params(jax.random.PRNGKey(seed), cfg))
    opt = init_opt(params)
    src = SyntheticLM(vocab=cfg.vocab, seq_len=128, global_batch=8, seed=seed)
    step = jax.jit(make_train_step(cfg, None, base_lr=2e-3, warmup=20,
                                   total_steps=steps, remat="none"))
    loss = None
    for i in range(steps):
        params, opt, m = step(params, opt,
                              {"tokens": jnp.asarray(src.batch(i))},
                              jnp.asarray(i))
        loss = float(m["loss"])
    return params, loss


# 1 ------------------------------------------------------------------------
print("== Table 5.1 proxy: multi-head (tied) vs per-channel filters ==")
t0 = time.time()
multi_params, multi_loss = train_model(make_cfg(8))
_, chan_loss = train_model(make_cfg(128))
print(f"MultiHyena (M=8 tied):     loss {multi_loss:.4f}")
print(f"Hyena (per-channel M=D):   loss {chan_loss:.4f}   ({time.time()-t0:.0f}s)")
RESULTS["table5.1"] = {"multihyena_loss": multi_loss, "hyena_loss": chan_loss}

# 2 ------------------------------------------------------------------------
print("\n== Fig 5.2: distillation error vs order (trained filters) ==")
cfg = make_cfg(8)
fp = jax.tree.map(lambda x: x[0], multi_params["groups"]["l0"]["mix"]["filter"])
h, _ = materialize_filters(fp, 512, cfg.hyena)
sv = hankel_singular_values(h)
print("Hankel sigma_n/sigma_1 at n=4,8,16,32:",
      [f"{float(jnp.max(sv[:, n] / sv[:, 0])):.1e}" for n in (4, 8, 16, 32)])
RESULTS["fig5.2"] = {"hankel_decay": {str(n): float(jnp.max(sv[:, n]/sv[:, 0]))
                                      for n in (4, 8, 16, 32)}, "err": {}}
for order in (4, 8, 16, 32):
    ssm, _ = distill_filters(h, order // 2, steps=2000)
    err = jnp.linalg.norm(eval_filter(ssm, 512) - h, axis=-1) / \
        jnp.linalg.norm(h, axis=-1)
    print(f"order {order:3d}: rel l2 err (min/mean/max) "
          f"{float(jnp.min(err)):.2e} {float(jnp.mean(err)):.2e} "
          f"{float(jnp.max(err)):.2e}")
    RESULTS["fig5.2"]["err"][str(order)] = float(jnp.max(err))

# 3 ------------------------------------------------------------------------
print("\n== Fig 5.1 / Table 5.2: logit error vs distillation order ==")
toks = jax.random.randint(jax.random.PRNGKey(3), (2, 96), 0, cfg.vocab)
full, _ = forward(multi_params, toks, cfg)
scale = float(jnp.max(jnp.abs(full)))
RESULTS["fig5.1"] = {}
for order in (4, 8, 16, 32):
    pd, _ = distill_model(multi_params, cfg, d=order, steps=2500, L=512)
    cache, last = prefill(pd, toks[:, :64], cfg, max_len=96)
    errs = [float(jnp.max(jnp.abs(last - full[:, 63])))]
    for t in range(64, 96):
        cache, lg = decode_step(pd, cache, toks[:, t:t + 1], cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    rel = max(errs) / scale
    print(f"order {order:3d}: relative logit error {rel:.4f}")
    RESULTS["fig5.1"][str(order)] = rel

# 4 ------------------------------------------------------------------------
print("\n== Sec 3.4: pre-filling strategies agree ==")
from repro.core import (init_modal, prefill_fft, prefill_recurrent,
                        prefill_scan, prefill_vandermonde)
ssm = init_modal(jax.random.PRNGKey(0), (16,), 8, r_minmax=(0.5, 0.95))
u = jax.random.normal(jax.random.PRNGKey(1), (16, 2048))
xr = prefill_recurrent(ssm, u)
s = float(jnp.max(jnp.abs(xr)))
agree = {}
for name, fn in (("scan", prefill_scan), ("vandermonde", prefill_vandermonde),
                 ("fft", prefill_fft)):
    err = float(jnp.max(jnp.abs(fn(ssm, u) - xr))) / s
    agree[name] = err
    print(f"{name:12s} vs recurrent: rel err {err:.2e}")
RESULTS["sec3.4"] = agree

json.dump(RESULTS, open("experiments/paper_validation.json", "w"), indent=1)
print("\nwrote experiments/paper_validation.json")
