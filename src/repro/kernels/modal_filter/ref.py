"""Pure-jnp oracle: modal filter materialization (Lemma 3.1).

h[c, 0] = h0[c];  h[c, t] = sum_n a^(t-1) (R_re cos(th (t-1)) - R_im sin(th (t-1)))
"""
import jax.numpy as jnp


def modal_filter_ref(log_a, theta, R_re, R_im, h0, L: int):
    """(C, d) params -> (C, L) filters."""
    t = jnp.arange(L - 1, dtype=jnp.float32)
    mag = jnp.exp(log_a[..., None] * t)                    # (C, d, L-1)
    ang = theta[..., None] * t
    tail = jnp.einsum("cd,cdl->cl", R_re, mag * jnp.cos(ang)) \
        - jnp.einsum("cd,cdl->cl", R_im, mag * jnp.sin(ang))
    return jnp.concatenate([h0[:, None], tail], axis=-1)
