"""Architecture registry. Importing this package registers all configs."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES, REGISTRY, get_config, list_archs,
    smoke_config, cell_applicable,
)

# side-effect registration — one module per assigned architecture
from repro.configs import mistral_nemo_12b   # noqa: F401
from repro.configs import llama3_2_3b        # noqa: F401
from repro.configs import gemma_7b           # noqa: F401
from repro.configs import starcoder2_3b      # noqa: F401
from repro.configs import qwen2_vl_72b       # noqa: F401
from repro.configs import whisper_medium     # noqa: F401
from repro.configs import recurrentgemma_9b  # noqa: F401
from repro.configs import granite_moe_3b_a800m  # noqa: F401
from repro.configs import dbrx_132b          # noqa: F401
from repro.configs import mamba2_130m        # noqa: F401
from repro.configs import multihyena_153m    # noqa: F401
from repro.configs import h3_125m            # noqa: F401

ASSIGNED = [
    "mistral-nemo-12b", "llama3.2-3b", "gemma-7b", "starcoder2-3b",
    "qwen2-vl-72b", "whisper-medium", "recurrentgemma-9b",
    "granite-moe-3b-a800m", "dbrx-132b", "mamba2-130m",
]
PAPER_ARCHS = ["multihyena-153m", "multihyena-1.3b", "h3-125m"]
