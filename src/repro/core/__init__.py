"""LaughingHyena distillation: the paper's primary contribution.

Pipeline (Fig. 3.1):
  1. materialize the pre-trained long-convolution filters h (M, L)
  2. analyze the Hankel spectrum to pick the target order d (Sec. 3.3)
  3. fit a modal-form SSM by gradient interpolation (Sec. 3.2)
  4. deploy: O(d) recurrent step + fast pre-filling (Sec. 3.4)
"""
from repro.core.modal import (  # noqa: F401
    eval_filter, modal_step, init_modal, ModalSSM,
)
from repro.core.hankel import (  # noqa: F401
    hankel_matrix, hankel_singular_values, suggest_order, aak_lower_bound,
)
from repro.core.distill import distill_filters, distill_model  # noqa: F401
from repro.core.transfer import (  # noqa: F401
    poly_from_roots, transfer_eval_fft, impulse_from_tf, get_tf_from_ss,
    companion_from_tf, companion_step,
)
from repro.core.prefill import (  # noqa: F401
    prefill_recurrent, prefill_scan, prefill_fft, prefill_vandermonde,
)
from repro.core.truncation import balanced_truncation, modal_truncation  # noqa: F401
