"""Qwen2-VL-72B [arXiv:2409.12191].

VLM: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064, M-RoPE.
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings that are concatenated ahead of the token embeddings.
"""
from repro.configs.base import ATTN, ModelConfig, register


@register
def qwen2_vl_72b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab=152064,
        act="swiglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        m_rope=True,
        m_rope_sections=(16, 24, 24),
        pattern=(ATTN,),
        frontend="vision_stub",
        frontend_len=256,
        max_seq=131072,
    )
