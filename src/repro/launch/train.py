"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch multihyena-153m \
      --smoke --steps 200 --batch 8 --seq 512 --ckpt /tmp/run1

Uses the local device set (tests/examples) or the production mesh under the
dry-run device flag. Supports restart (--ckpt), remat policy, grad accum and
MoE implementation selection.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.data.pipeline import SyntheticLM, MemmapTokens, make_batches
from repro.distributed.sharding import TRAIN_RULES, tree_shardings, unzip
from repro.launch.mesh import make_local_mesh
from repro.models.model import init_params
from repro.train.checkpoint import Checkpointer
from repro.train.loop import train
from repro.train.train_step import init_opt, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", type=str, default=None, help=".bin memmap path")
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--moe-impl", default="dropless")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = None
    if args.data_par * args.model_par > 1:
        mesh = make_local_mesh(args.data_par, args.model_par)

    key = jax.random.PRNGKey(args.seed)
    ptree = init_params(key, cfg)
    params, axes = unzip(ptree)
    if mesh is not None:
        shardings = tree_shardings(params, axes, TRAIN_RULES, mesh)
        params = jax.device_put(params, shardings)
    opt = init_opt(params)
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"[launch] {cfg.name}: {n/1e6:.1f}M params, mesh={mesh}", flush=True)

    if args.data:
        src = MemmapTokens(args.data, vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch)
    else:
        src = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)

    step_fn = jax.jit(make_train_step(
        cfg, mesh, base_lr=args.lr, warmup=max(args.steps // 20, 1),
        total_steps=args.steps, moe_impl=args.moe_impl, remat=args.remat,
        accum=args.accum, grad_compression=args.grad_compression))

    ckpt = Checkpointer(args.ckpt) if args.ckpt else None
    start = (ckpt.latest_step() + 1) if (ckpt and ckpt.latest_step() is not None) else 0
    t0 = time.time()
    out = train(step_fn, params, opt,
                make_batches(src, mesh, start_step=start),
                steps=args.steps, ckpt=ckpt, ckpt_every=args.ckpt_every)
    dt = time.time() - t0
    toks = (out["step"] + 1 - start) * args.batch * args.seq
    print(f"[launch] done: step={out['step']} loss={float(out['metrics']['loss']):.4f} "
          f"({toks/dt:.0f} tok/s, stragglers={out['straggler_count']})")


if __name__ == "__main__":
    main()
