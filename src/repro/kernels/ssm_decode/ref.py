"""Pure-jnp oracle: fused modal-SSM decode step (Prop. 3.3, paper convention).

y_t = Re[R . x_t] + h0 u_t ;  x_{t+1} = lam x_t + u_t   (B, C, d) state.
"""
import jax.numpy as jnp


def ssm_decode_ref(x_re, x_im, u, log_a, theta, R_re, R_im, h0):
    """x: (B,C,d); u: (B,C); params (C,d)/(C,). Returns (y, x_re', x_im')."""
    y = jnp.einsum("bcd,cd->bc", x_re, R_re) - jnp.einsum("bcd,cd->bc", x_im, R_im)
    y = y + h0[None, :] * u
    lr = jnp.exp(log_a) * jnp.cos(theta)
    li = jnp.exp(log_a) * jnp.sin(theta)
    nxr = lr[None] * x_re - li[None] * x_im + u[..., None]
    nxi = lr[None] * x_im + li[None] * x_re
    return y, nxr, nxi
