"""Fast pre-filling strategies (paper Sec. 3.4).

Given a prompt u (..., T) and a modal SSM, compute the post-prompt state
x_T = sum_{j<T} lam^(T-1-j) u_j with one of four strategies with different
time/memory trade-offs (Lemma 2.2, Prop. 3.2):

  recurrent   — O(dT) sequential scan, O(d) memory
  scan        — associative scan, O(d log T) parallel time, O(dT) memory
  vandermonde — O(dT) as one (d x T) matmul; MXU-friendly (our TPU adaptation)
  fft         — O~(T): companion-form state via circular deconvolution
                (Prop. 3.2), then a d^2 basis change back to modal form
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.modal import ModalSSM
from repro.core.transfer import poly_from_roots


def _lam(ssm: ModalSSM) -> jnp.ndarray:
    return jnp.exp(ssm.log_a.astype(jnp.complex64) + 1j * ssm.theta)


def prefill_recurrent(ssm: ModalSSM, u: jnp.ndarray) -> jnp.ndarray:
    """u: (..., T) -> x_T (..., d) complex. Sequential scan."""
    lam = _lam(ssm)

    def body(x, ut):
        return lam * x + ut[..., None], None

    x0 = jnp.zeros(ssm.log_a.shape, jnp.complex64)
    xT, _ = jax.lax.scan(body, x0, jnp.moveaxis(u.astype(jnp.complex64), -1, 0))
    return xT


def prefill_scan(ssm: ModalSSM, u: jnp.ndarray) -> jnp.ndarray:
    """Parallel associative scan (Blelloch), O(d log T) depth, O(dT) memory."""
    lam = _lam(ssm)
    T = u.shape[-1]
    a = jnp.broadcast_to(lam[..., None, :], u.shape + (lam.shape[-1],))
    b = jnp.broadcast_to(u[..., None].astype(jnp.complex64),
                         u.shape + (lam.shape[-1],))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, x = jax.lax.associative_scan(combine, (a, b), axis=-2)
    return x[..., -1, :]


def prefill_vandermonde(ssm: ModalSSM, u: jnp.ndarray) -> jnp.ndarray:
    """x_T as a Vandermonde-basis matmul — one big MXU-friendly contraction."""
    T = u.shape[-1]
    expo = jnp.arange(T - 1, -1, -1, dtype=jnp.float32)
    logl = ssm.log_a.astype(jnp.complex64) + 1j * ssm.theta
    basis = jnp.exp(logl[..., None] * expo)                # (..., d, T)
    return jnp.einsum("...dt,...t->...d", basis, u.astype(jnp.complex64))


def prefill_fft(ssm: ModalSSM, u: jnp.ndarray) -> jnp.ndarray:
    """Prop. 3.2: O~(T) FFT pre-filling.

    nu = (1/p) * u computed by circular deconvolution (valid up to rho(A)^T
    wrap-around, App. A.4), companion state x_T^comp = (nu_{T-1},...,nu_{T-d}),
    then map to the modal state with the deflated-polynomial basis change
    x_n = sum_i q_n[i] nu_{T-1-(d-1-i)} where q_n = p(z)/(z - lam_n).
    """
    lam = _lam(ssm)
    d = lam.shape[-1]
    T = u.shape[-1]
    p = poly_from_roots(lam)                               # (..., d+1) monic
    P = jnp.fft.fft(jnp.concatenate(
        [p, jnp.zeros(p.shape[:-1] + (T - d - 1,), p.dtype)], axis=-1), axis=-1)
    U = jnp.fft.fft(u.astype(jnp.complex64), axis=-1)
    nu = jnp.fft.ifft(U / P, axis=-1)                      # (..., T)
    # companion state: last d values of nu, newest first
    xc = nu[..., -1:-(d + 1):-1]                           # (nu_{T-1},...,nu_{T-d})
    # q_n(z) = p(z)/(z - lam_n) by synthetic division (coeffs descending)
    def deflate(p_full, r):
        def body(carry, coef):
            q = coef + r * carry
            return q, q
        _, qs = jax.lax.scan(body, jnp.zeros_like(r),
                             jnp.moveaxis(p_full[..., :-1], -1, 0))
        return jnp.moveaxis(qs, 0, -1)                     # (..., d)

    qn = jax.vmap(lambda rr: deflate(p, rr), in_axes=-1, out_axes=-2)(lam)
    # modal x_n,T = sum_{i=0}^{d-1} q_n[i] * v_{T-1-i}  (q_n in z^-1 form)
    return jnp.einsum("...ni,...i->...n", qn, xc)
