"""DBRX-132B [hf:databricks/dbrx-base; unverified].

Fine-grained MoE: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 per expert,
16 experts top-4, vocab=100352.
"""
from repro.configs.base import ATTN, MLP_MOE, MoEConfig, ModelConfig, register


@register
def dbrx_132b() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab=100352,
        act="swiglu",
        norm="rmsnorm",
        rope_theta=500_000.0,
        pattern=(ATTN,),
        mlp_kind=MLP_MOE,
        moe=MoEConfig(n_experts=16, top_k=4),
        max_seq=32768,
    )
