"""Matched-size model pairs for the paper's benchmark comparisons.

The paper benchmarks Transformers vs Hyena vs LaughingHyena at equal sizes
(Sec. 5.4). On this CPU container we use reduced widths; the comparison
STRUCTURE (kv-cache vs cached-conv vs recurrent) is identical to Fig 1.1.
"""
import jax

from repro.configs.base import ATTN, HYENA, HyenaConfig, ModelConfig
from repro.core.distill import distill_model
from repro.distributed.sharding import unzip
from repro.models.model import init_params

D, L_LAYERS, VOCAB = 128, 4, 512


def transformer_cfg() -> ModelConfig:
    return ModelConfig(name="bench-transformer", family="dense",
                       n_layers=L_LAYERS, d_model=D, n_heads=4, n_kv_heads=4,
                       head_dim=32, d_ff=4 * D, vocab=VOCAB, act="gelu",
                       norm="layernorm", pattern=(ATTN,), max_seq=65536,
                       dtype="float32")


def hyena_cfg(distill_order: int = 16) -> ModelConfig:
    return ModelConfig(name="bench-multihyena", family="lcsm",
                       n_layers=L_LAYERS, d_model=D, n_heads=4, n_kv_heads=4,
                       head_dim=32, d_ff=4 * D, vocab=VOCAB, act="gelu",
                       norm="layernorm", pattern=(HYENA,),
                       hyena=HyenaConfig(n_filter_heads=4, filter_order=32,
                                         filter_emb=17,
                                         distill_order=distill_order),
                       max_seq=65536, dtype="float32")


def sentinel_cfg() -> ModelConfig:
    """Small config whose distillation is near-exact (distill_order high
    relative to the serving horizon), for the drift-sentinel chaos row.

    The sentinel can only flag drift LARGER than the genuine distillation
    error — that floor is exactly what the static certificate reports. The
    bench-size model above distills with a loose certificate (l1 ~ 4), so a
    deterministic detection demo needs a tight one: this config's clean
    shadow divergence is ~1e-2 against ~2+ for a sign-flipped state."""
    return ModelConfig(name="bench-sentinel", family="lcsm", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, vocab=64, act="gelu", norm="layernorm",
                       pattern=(HYENA,),
                       hyena=HyenaConfig(n_filter_heads=2, filter_order=16,
                                         filter_emb=9, distill_order=32),
                       max_seq=512, dtype="float32")


def build(cfg, key=0, distill: bool = False, distill_len: int = 1024):
    params, _ = unzip(init_params(jax.random.PRNGKey(key), cfg))
    if distill:
        params, _ = distill_model(params, cfg, steps=800, L=distill_len)
    return params
