from repro.models.model import (  # noqa: F401
    init_params, forward, train_loss, decode_step, init_cache, prefill,
)
