"""Epoched-FFT exact serving path + online distillation-drift sentinel.

The epoch cache kind (FutureFill-style epoched convolution) is an EXACT
realization of the long convolution: greedy decode through it must be
token-identical to the cached-conv path in every serving configuration
(plain, chunked prefill, speculative, checkpoint/restore). The drift
sentinel shadow-verifies the distilled engine against this exact path and
demotes the engine down the mode ladder when the divergence exceeds the
tolerance.

The sentinel tests run on a model whose distillation is near-exact
(distill_order high relative to the 48-token horizon): the sentinel can
only flag drift LARGER than the genuine distillation error, so the clean
shadow divergence must sit well below the tolerance (here ~1e-2 vs 0.5)
while a sign-flipped state sits well above (~2+). The injected fault
(value=-2.0 => state scaled by -1) is norm-preserving, so the norm-margin
health guard cannot catch it — only the sentinel can.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ATTN, HYENA, HyenaConfig, ModelConfig
from repro.core.distill import distill_model, distillation_certificate
from repro.distributed.sharding import unzip
from repro.models.model import init_params
from repro.serve.checkpoint import restore_engine, save_engine
from repro.serve.engine import GenerationEngine
from repro.serve.faults import FaultInjector
from repro.serve.scheduler import ContinuousBatchingEngine

MAX_LEN = 48
PROMPT_LENS = (5, 9, 17, 12)
GEN = 10


def _cfg():
    return ModelConfig(name="epoch-hyena", family="lcsm", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, vocab=64, act="gelu", norm="layernorm",
                       pattern=(HYENA,),
                       hyena=HyenaConfig(n_filter_heads=2, filter_order=16,
                                         filter_emb=9, distill_order=32),
                       max_seq=512, dtype="float32")


@pytest.fixture(scope="module")
def distilled_model():
    cfg = _cfg()
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    params, _ = distill_model(params, cfg, steps=400, L=MAX_LEN)
    return cfg, params


def _prompts(vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32)
            for n in PROMPT_LENS]


def _run(cfg, params, mode, **kw):
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode=mode, **kw)
    reqs = [eng.submit(p, max_new_tokens=GEN) for p in _prompts(cfg.vocab)]
    eng.run()
    assert all(r.status == "finished" for r in reqs), \
        [(r.rid, r.status) for r in reqs]
    return {r.rid: list(r.tokens) for r in reqs}


# ---------------------------------------------------------------------------
# exactness: epoch == cached_conv, token for token
# ---------------------------------------------------------------------------
def test_epoch_matches_cached_conv_scheduler(distilled_model):
    """Greedy token identity through the slot pool: the epoched convolution
    is exact, so it must reproduce the cached-conv reference bit-for-bit
    (bucketed prefill, queueing, slot reuse all exercised)."""
    cfg, params = distilled_model
    assert _run(cfg, params, "epoch") == _run(cfg, params, "cached_conv")


def test_epoch_chunked_prefill_identity(distilled_model):
    """Chunked prefill through the epoch kind (entry flush + widened decode
    window + end flush) changes nothing."""
    cfg, params = distilled_model
    want = _run(cfg, params, "cached_conv")
    assert _run(cfg, params, "epoch", prefill_chunk=4) == want


def test_epoch_speculative_identity(distilled_model):
    """Self-speculation over the epoch pool (native-kind draft, multi-token
    verify through the epoched conv) stays token-identical."""
    cfg, params = distilled_model
    want = _run(cfg, params, "cached_conv")
    assert _run(cfg, params, "epoch", spec_k=2, spec_adapt=False) == want


def test_epoch_generation_engine_long_decode(distilled_model):
    """Single-request decode far past several epoch flush boundaries
    (epoch tail E=8 at max_len=48) matches cached-conv exactly."""
    cfg, params = distilled_model
    prompt = jnp.asarray(_prompts(cfg.vocab)[1])[None]
    outs = []
    for mode in ("cached_conv", "epoch"):
        eng = GenerationEngine(params, cfg, max_len=MAX_LEN, mode=mode)
        toks, _ = eng.generate(jax.random.PRNGKey(1), prompt, 30)
        outs.append(np.asarray(toks[0]))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_epoch_mode_validation(distilled_model):
    cfg, params = distilled_model
    with pytest.raises(ValueError, match="mode"):
        ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                 mode="nonsense")
    acfg = ModelConfig(name="epoch-attn", family="dense", n_layers=1,
                       d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, vocab=64, act="gelu", norm="layernorm",
                       pattern=(ATTN,), max_seq=512, dtype="float32")
    aparams, _ = unzip(init_params(jax.random.PRNGKey(0), acfg))
    with pytest.raises(ValueError, match="Hyena"):
        ContinuousBatchingEngine(aparams, acfg, n_slots=2, max_len=MAX_LEN,
                                 mode="epoch")


# ---------------------------------------------------------------------------
# checkpoint / restore across the mode ladder
# ---------------------------------------------------------------------------
def test_epoch_checkpoint_restore_bit_exact(distilled_model, tmp_path):
    """Mid-run snapshot of an epoch engine restores into a fresh epoch
    engine and finishes token-identically to an uninterrupted run."""
    cfg, params = distilled_model
    want = _run(cfg, params, "epoch")
    path = str(tmp_path / "epoch.ckpt")

    eng_a = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                     mode="epoch")
    for p in _prompts(cfg.vocab):
        eng_a.submit(p, max_new_tokens=GEN)
    for _ in range(8):
        if eng_a.has_work:
            eng_a.step()
    save_engine(eng_a, path)
    del eng_a

    eng_b = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                     mode="epoch")
    restore_engine(eng_b, path)
    eng_b.run()
    assert {r.rid: list(r.tokens) for r in eng_b.finished} == want


def test_checkpoint_ladder_demotion_replay(distilled_model):
    """A snapshot taken after the engine walked down the mode ladder
    restores into a fresh higher-mode engine by replaying the demotion; the
    reverse direction (up-ladder) is rejected with a clear error."""
    cfg, params = distilled_model
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode="distilled")
    for p in _prompts(cfg.vocab):
        eng.submit(p, max_new_tokens=GEN)
    for _ in range(4):
        eng.step()
    eng._demote_engine("epoch")
    assert eng.mode == "epoch" and eng._cache_kind == "epoch"
    state = save_engine(eng)

    fresh = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                     mode="distilled")
    restore_engine(fresh, state)
    assert fresh.mode == "epoch" and fresh._cache_kind == "epoch"
    fresh.run()
    assert all(r.status in ("finished", "error") for r in fresh.finished)
    assert len(fresh.finished) == len(PROMPT_LENS)

    # up-ladder: a distilled snapshot cannot restore into an epoch engine
    dist = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                    mode="distilled")
    upstate = save_engine(dist)
    target = ContinuousBatchingEngine(params, cfg, n_slots=2,
                                      max_len=MAX_LEN, mode="epoch")
    with pytest.raises(ValueError, match="mode"):
        restore_engine(target, upstate)


# ---------------------------------------------------------------------------
# drift sentinel: shadow-verify, alarm, demote
# ---------------------------------------------------------------------------
def test_sentinel_clean_run_no_alarms(distilled_model):
    """On a healthy well-distilled engine the sentinel's shadow divergence
    stays far below the tolerance: checks fire, no alarms, no demotion."""
    cfg, params = distilled_model
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode="distilled", drift_check_every=2,
                                   drift_tol=0.5)
    for p in _prompts(cfg.vocab):
        eng.submit(p, max_new_tokens=12)
    eng.run()
    assert eng.resilience.get("drift_checks") > 0
    assert eng.resilience.get("drift_alarms") == 0
    assert eng.mode == "distilled"
    assert eng._drift_last is not None and eng._drift_last < 0.5
    h = eng.metrics.get("serve_drift_logit_div")
    assert h.count == eng.resilience.get("drift_checks")


def test_sentinel_detects_silent_drift_and_demotes(distilled_model):
    """A sign-flip drift fault (norm-preserving, invisible to the health
    guard) trips the sentinel: drift_alarm event, engine demoted straight to
    the exact epoch path, sentinel disarmed, and every request still reaches
    a terminal status."""
    cfg, params = distilled_model
    inj = FaultInjector([{"tick": 6, "kind": "drift", "value": -2.0,
                          "slot": 0}], seed=0)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode="distilled", drift_check_every=2,
                                   drift_tol=0.5, fault_injector=inj)
    reqs = [eng.submit(p, max_new_tokens=12) for p in _prompts(cfg.vocab)]
    eng.run()
    assert [e for e in inj.log if e["kind"] == "drift"]
    assert eng.resilience.get("drift_alarms") >= 1
    assert eng.resilience.get("engine_demotions") == 1
    assert eng.mode == "epoch" and eng._cache_kind == "epoch"
    assert eng._sentinel is False          # disarmed after demotion
    assert any(e["kind"] == "drift_alarm" for e in eng.events)
    assert all(r.status in ("finished", "error") for r in reqs)
    assert len(eng.finished) == len(reqs)


def test_sentinel_ignored_outside_distilled_mode(distilled_model):
    """drift_check_every on a non-distilled engine is a no-op (there is no
    approximation to verify): no checks, no histogram samples."""
    cfg, params = distilled_model
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode="epoch", drift_check_every=2)
    assert eng._sentinel is False
    for p in _prompts(cfg.vocab):
        eng.submit(p, max_new_tokens=GEN)
    eng.run()
    assert eng.resilience.get("drift_checks") == 0


def test_sentinel_zero_steady_state_compiles(distilled_model):
    """warmup() warms the sentinel's shadow executables (epoch prefill at
    every bucket, row gather, shadow decode): a warmed stream with checks
    firing compiles nothing."""
    from repro.serve.metrics import count_compiles
    cfg, params = distilled_model
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode="distilled", drift_check_every=2)
    eng.warmup(PROMPT_LENS)
    with count_compiles() as scope:
        for p in _prompts(cfg.vocab):
            eng.submit(p, max_new_tokens=GEN)
        eng.run()
    assert eng.resilience.get("drift_checks") > 0
    assert scope.compiles == 0, f"{scope.compiles} steady-state compiles"


# ---------------------------------------------------------------------------
# certificates
# ---------------------------------------------------------------------------
def test_distillation_certificate_sanity(distilled_model):
    cfg, params = distilled_model
    cert = distillation_certificate(params, cfg, MAX_LEN)
    assert cert["horizon"] == MAX_LEN
    assert cert["layers"] and all(k.startswith("l") for k in cert["layers"])
    total = 0.0
    for layer in cert["layers"].values():
        assert 0.0 <= layer["max_abs"] <= layer["l1"] < float("inf")
        total += layer["l1"]
    assert cert["total_l1"] == pytest.approx(total)
    # near-exact distillation => tight certificate
    assert cert["total_l1"] < 0.5
    # the engine surfaces the same certificate lazily
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode="distilled")
    assert eng.drift_certificate["total_l1"] == pytest.approx(
        cert["total_l1"], rel=1e-5)


def test_truncation_certificate_bounds_measured_error():
    """Deterministic version of the hypothesis property (tier-1 runs
    without hypothesis): the per-position certificate curve upper-bounds
    the measured |full - truncated| filter error, refit=False."""
    from repro.core import eval_filter, init_modal
    from repro.core.truncation import (modal_truncation,
                                       truncation_error_certificate)
    L, d, keep = 96, 6, 3
    for seed in (0, 1, 2):
        ssm = init_modal(jax.random.PRNGKey(seed), (1,), d,
                         r_minmax=(0.2, 0.95))
        cert = truncation_error_certificate(ssm, keep, L)
        full = np.asarray(eval_filter(ssm, L), np.float64)[0]
        trunc = np.asarray(eval_filter(modal_truncation(ssm, keep), L),
                           np.float64)[0]
        err = np.abs(full - trunc)
        curve = np.asarray(cert["curve"], np.float64)[0]
        assert curve[0] == 0.0 and err[0] < 1e-6
        assert np.all(err <= curve + 1e-4), (seed, (err - curve).max())
        assert err[1:].sum() <= float(cert["l1_bound"][0]) + 1e-3
