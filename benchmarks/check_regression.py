"""Serving-benchmark regression gate.

Compares a fresh `make bench-serve` run against the committed baseline
(BENCH_serve.json at the repo root) and fails if any serve_stream mode's
throughput dropped by more than the threshold (default 15%). Also enforces
the speculative-decoding floor: the `distilled_spec` mode must report
decode tok/s at least `--spec-floor` (default 1.3x) times the BASELINE
distilled mode's tok/s — the PR-3 acceptance criterion, kept as a ratchet.

  PYTHONPATH=src python -m benchmarks.check_regression \
      --baseline BENCH_baseline.json --new BENCH_serve.json

CI runs this with the committed file as baseline (copied aside before the
bench overwrites it).
"""
from __future__ import annotations

import argparse
import json
import sys


def _modes(doc):
    return doc.get("serve_stream", {}).get("modes", {})


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_serve.json to compare against")
    ap.add_argument("--new", default="BENCH_serve.json",
                    help="freshly produced benchmark file")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated fractional tok/s drop per mode")
    ap.add_argument("--spec-floor", type=float, default=1.3,
                    help="when the BASELINE predates speculative decoding "
                         "(no distilled_spec mode), require the new "
                         "distilled_spec decode tok/s to reach this multiple "
                         "of the baseline distilled tok/s (0 disables). "
                         "Once the baseline itself contains distilled_spec, "
                         "the ordinary per-mode drop check covers it — an "
                         "absolute multiple of the ever-faster committed "
                         "distilled number would ratchet unsatisfiably.")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = _modes(json.load(f))
    with open(args.new) as f:
        new = _modes(json.load(f))

    failures = []
    for mode, bm in sorted(base.items()):
        nm = new.get(mode)
        if nm is None:
            failures.append(f"mode {mode!r} disappeared from the new run")
            continue
        old_tps, new_tps = bm["tok_per_s"], nm["tok_per_s"]
        floor = old_tps * (1.0 - args.threshold)
        status = "ok" if new_tps >= floor else "REGRESSION"
        print(f"[bench-check] {mode:15s} {old_tps:8.1f} -> {new_tps:8.1f} "
              f"tok/s (floor {floor:.1f}) {status}")
        if new_tps < floor:
            failures.append(
                f"{mode}: tok/s dropped {old_tps:.1f} -> {new_tps:.1f} "
                f"(> {args.threshold:.0%})")

    if args.spec_floor > 0 and "distilled" in base \
            and "distilled_spec" not in base:
        spec = new.get("distilled_spec")
        if spec is None:
            failures.append("distilled_spec mode missing from the new run")
        else:
            ref = base["distilled"]["tok_per_s"]
            got = spec.get("decode_tok_per_s", spec["tok_per_s"])
            need = args.spec_floor * ref
            status = "ok" if got >= need else "BELOW FLOOR"
            print(f"[bench-check] distilled_spec decode {got:.1f} tok/s vs "
                  f"{args.spec_floor:.2f}x baseline distilled "
                  f"({ref:.1f}) = {need:.1f} {status}")
            if got < need:
                failures.append(
                    f"distilled_spec decode tok/s {got:.1f} < "
                    f"{args.spec_floor:.2f}x baseline distilled {ref:.1f}")

    if failures:
        for msg in failures:
            print(f"[bench-check] FAIL: {msg}", file=sys.stderr)
        return 1
    print("[bench-check] all serving throughput checks passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
