"""MultiHyena multi-head structure (paper Sec. 4 / Thm 4.1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.hyena import fft_conv, outer_product_op


def test_outer_product_op_reduces_to_elementwise_at_N1():
    """With N = D/M = 1 the Sec.-4 operator equals elementwise Hyena gating
    y = q * (h * (k.v)) — the deployed form's correctness anchor."""
    B, L, D = 2, 64, 8
    M = D                       # one channel per head
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(jax.random.PRNGKey(i), (B, L, D))
               for i in range(3))
    h = jax.random.normal(key, (M, L)) * 0.2
    ref = q * fft_conv(k * v, h)
    out = outer_product_op(q, k, v, h, M)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_outer_product_op_is_linear_attention_with_toeplitz_mask():
    """y_t = sum_j h_{t-j} k_j (v_j . q_t): verify against the quadratic
    formulation (C.9/C.11 of the Thm 4.1 proof)."""
    B, L, D, M = 1, 32, 8, 2
    N = D // M
    key = jax.random.PRNGKey(1)
    q, k, v = (jax.random.normal(jax.random.PRNGKey(10 + i), (B, L, D)) * 0.5
               for i in range(3))
    h = jax.random.normal(key, (M, L)) * 0.3
    out = outer_product_op(q, k, v, h, M)
    qh = q.reshape(B, L, M, N)
    kh = k.reshape(B, L, M, N)
    vh = v.reshape(B, L, M, N)
    # quadratic reference
    ref = np.zeros((B, L, M, N), np.float32)
    hq = np.asarray(h)
    for t in range(L):
        for j in range(t + 1):
            w = hq[:, t - j]                               # (M,)
            dot = np.einsum("bmn,bmn->bm", np.asarray(vh[:, j]),
                            np.asarray(qh[:, t]))
            ref[:, t] += w[None, :, None] * dot[..., None] * np.asarray(kh[:, j])
    np.testing.assert_allclose(np.asarray(out).reshape(B, L, M, N), ref,
                               atol=1e-3)


def test_associative_recall_state_is_constant_memory():
    """The distilled multi-head operator keeps O(M d N^2)-independent state in
    the deployed (elementwise) form: cache size independent of sequence len."""
    from repro.configs import get_config, smoke_config
    from repro.models.hyena import init_hyena_cache
    cfg = smoke_config(get_config("multihyena-153m"))
    c1 = init_hyena_cache(4, cfg)
    bytes_ = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c1))
    # d_model * distill_order reals per channel x2 (re/im) + short conv
    d = cfg.d_model
    expect = 4 * (2 * d * cfg.hyena.distill_order // 2 +
                  (cfg.hyena.short_conv - 1) * 3 * d) * 4
    assert bytes_ == expect
