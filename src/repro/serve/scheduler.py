"""Continuous-batching scheduler: a fixed pool of B state slots.

The paper's point of distilling Hyena filters into modal SSMs is O(1)
compute/memory per token at decode — which makes multi-request serving a
*slot* problem rather than a paged-KV problem: every request's entire decode
state is a fixed-size row of a pooled cache (modal SSM state, conv tail, or
kv/conv buffers for the baseline modes). This module schedules requests onto
those rows:

  * admission   — queued requests are prefilled and their caches scattered
                  into free slots. Prompts are right-padded to power-of-two
                  length BUCKETS and prefilled together as ONE fixed-batch
                  call (per-row `lengths` masking keeps padded positions out
                  of every cache), so the engine compiles O(#buckets) prefill
                  executables instead of O(#distinct lengths) and admission
                  cost amortizes across a burst of arrivals;
  * chunking    — prompts longer than `prefill_chunk` run through the
                  resumable `prefill_from_cache` path: one chunk-sized
                  executable covers any prompt length, and only one chunk is
                  consumed per tick, so a long prompt never stalls resident
                  decodes for more than one chunk;
  * decode      — ONE jitted `decode_step` over the full slot pool per tick,
                  each slot at its own position (per-slot `pos` vector);
                  inactive slots decode garbage that is ignored and fully
                  overwritten on readmission;
  * overlap     — the host loop exploits JAX async dispatch: tick N is
                  enqueued from device-resident last-token state BEFORE tick
                  N-1's sampled tokens are fetched to host, so EOS/eviction
                  bookkeeping and admissions run while the device crunches
                  the next step (`overlap=False` restores the fully
                  synchronous admit-then-decode tick);
  * sampling    — per-slot temperature/top-k/top-p in one batched jitted
                  `sample_token_slots` call, parameter vectors resident on
                  device and updated by a scatter at admission;
  * eviction    — on EOS or max-new-tokens the slot is freed (and optionally
                  zeroed) and the next queued request admitted.

Deployment modes (paper Sec. 2.2 / 5.4): "distilled" (LaughingHyena modal
recurrence), "cached_conv" (Lemma 2.1 O(t) baseline), "epoch" (FutureFill
epoched convolution — exact at amortized O(sqrt(L) log L) per token), and
the native mode of non-LCSM archs (attention KV cache, Mamba2/RG-LRU state).

Guarantee (tested): greedy outputs are token-for-token identical to
sequential single-request generation with bucketing, chunking, and the
overlapped loop all enabled. With temperature > 0 the per-request token
*distributions* are unchanged but the PRNG consumption order differs between
overlapped and synchronous runs.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import (SLOT_RULES, slot_axes, tree_shardings,
                                        unzip)
from repro.models.layers import NOCTX, ShardCtx
from repro.models.model import (gather_cache_rows, init_cache,
                                init_prefill_cache,
                                materialize_conv_filters, modal_state_bound,
                                reset_cache_slot, slot_health,
                                write_cache_slot, write_cache_slots)
from repro.serve.faults import FaultError, corrupt_cache_slot, drift_cache_slot
from repro.serve.metrics import (DRIFT_BUCKETS, MetricsRegistry,
                                 RATIO_BUCKETS, ResilienceCounters,
                                 WINDOW_BUCKETS)
from repro.serve.sampling import sample_token_slots
from repro.serve.trace import NULL_TRACER
from repro.serve.speculative import DRAW_TAG, token_keys

QUEUED, PREFILLING, RUNNING, FINISHED, ERROR = (
    "queued", "prefilling", "running", "finished", "error")

# Engine recovery ladder (serve/README.md "Exact fallback & drift sentinel"):
# distilled (O(d)/token, distillation error) -> cached_conv (exact, O(t)) ->
# epoch (exact, amortized O(sqrt(L) log L) — FutureFill). Demotions only walk
# right.
MODE_LADDER = ("distilled", "cached_conv", "epoch")
_MODE_KINDS = {"distilled": "native", "cached_conv": "conv", "epoch": "epoch"}

_SLOT_JITS: Dict[Any, Callable] = {}


def _log_softmax_np(x: np.ndarray) -> np.ndarray:
    """Host-side log-softmax over the last axis (drift-sentinel compare)."""
    x = x - x.max(axis=-1, keepdims=True)
    return x - np.log(np.exp(x).sum(axis=-1, keepdims=True))


def _jitted(name: str, fn, *, key=None, **jit_kw):
    """Shared jit memo for the slot-vector ops. `key` extends the memo key
    for variants whose jit options differ (a sharded engine pins
    out_shardings, so it cannot share the single-device executable)."""
    k = (name, key)
    if k not in _SLOT_JITS:
        _SLOT_JITS[k] = jax.jit(fn, **jit_kw)
    return _SLOT_JITS[k]


def _update_slot_meta(temps, top_ks, top_ps, last, keys, tok_idx, spec_len,
                      slots, t, k, p, tok, kv, ti, sl):
    """Scatter per-slot sampling params, request PRNG keys, stream counters
    and speculation windows + last token for newly admitted requests.
    Out-of-range slot indices (dummy admission rows) are dropped by an
    explicit mask — the same scatter-max marker as
    `model.write_cache_slots`, because OOB-index scatter semantics are not
    partition-stable on a sharded slot vector."""
    B = temps.shape[0]
    K = slots.shape[0]
    valid = (slots >= 0) & (slots < B)
    src = jnp.where(valid, jnp.arange(K, dtype=jnp.int32), -1)
    marker = jnp.full((B,), -1, jnp.int32).at[
        jnp.where(valid, slots, 0)].max(src)
    take_idx = jnp.maximum(marker, 0)
    keep = marker >= 0

    def put(vec, vals):
        g = jnp.take(vals.astype(vec.dtype), take_idx, axis=0)
        return jnp.where(keep.reshape((B,) + (1,) * (vec.ndim - 1)), g, vec)

    return (put(temps, t), put(top_ks, k), put(top_ps, p), put(last, tok),
            put(keys, kv), put(tok_idx, ti), put(spec_len, sl))


def _admit_sample(keyvec, logits, t, k, p):
    """First-token draw at admission: stream index 0 of each request's key
    tree (identical to what the decode loop would have drawn)."""
    keys = token_keys(keyvec, jnp.zeros((keyvec.shape[0],), jnp.int32),
                      DRAW_TAG)
    return sample_token_slots(keys, logits, temperature=t, top_k=k, top_p=p)


def _stream_sample(slot_keys, tok_idx, logits, temps, top_ks, top_ps):
    """Non-speculative decode draw: per-slot DRAW_TAG key at each slot's own
    stream index — the same key tree the speculative path consumes."""
    keys = token_keys(slot_keys, tok_idx, DRAW_TAG)
    toks = sample_token_slots(keys, logits, temperature=temps, top_k=top_ks,
                              top_p=top_ps)
    return toks, tok_idx + 1


def _slot_health_state(cache, bound):
    """Spec-path guard: cache-state-only (the fused spec round does not
    expose its verify logits). Covers the modal state and conv tails — the
    distilled serving path — while sequence-buffer corruption in a
    cached-conv spec engine surfaces as degenerate (argmax-fallback) tokens
    rather than a tripped guard."""
    B = jnp.asarray(cache["pos"]).shape[0]
    return slot_health(cache, jnp.zeros((B, 1), jnp.float32), bound)


def _clear_slot_meta(temps, top_ks, top_ps, spec_len, slot):
    """Reset a freed slot's sampling params and speculation window to the
    neutral values (greedy, window 1). Stale values on dead slots would
    otherwise defeat the all-greedy and all-fully-accepted fast paths (the
    fused executables branch on jnp.all over EVERY row, dead or alive).
    One-hot select rather than a scatter: slot == n_slots (the warmup dummy)
    matches no row, and the select is partition-stable on a sharded
    vector."""
    hit = jnp.arange(temps.shape[0], dtype=jnp.int32) == slot
    return (jnp.where(hit, jnp.float32(0.0), temps),
            jnp.where(hit, jnp.int32(0), top_ks),
            jnp.where(hit, jnp.float32(1.0), top_ps),
            jnp.where(hit, jnp.int32(1), spec_len))


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0       # <= 0 -> greedy
    top_k: int = 0                 # <= 0 -> disabled
    top_p: float = 1.0             # >= 1 -> disabled

GREEDY = SamplingParams()


@dataclasses.dataclass
class Request:
    """One generation request plus its lifecycle/latency bookkeeping."""
    rid: int
    prompt: np.ndarray                       # (T,) int32
    max_new_tokens: int
    sampling: SamplingParams = GREEDY
    eos_id: Optional[int] = None
    spec: bool = True                        # opt out of speculative decode
    deadline_s: Optional[float] = None       # end-to-end budget from submit
    # --- filled by the engine ---
    tokens: List[int] = dataclasses.field(default_factory=list)
    status: str = QUEUED
    slot: int = -1
    finish_reason: str = ""
    retries: int = 0                         # quarantine re-prefill attempts
    retry_at: int = 0                        # earliest tick for re-admission
    admit_seq: int = -1                      # dispatch seq at latest admission
    t_submit: float = math.nan
    t_admitted: float = math.nan
    t_first_token: float = math.nan
    t_finished: float = math.nan

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def latency(self) -> float:
        return self.t_finished - self.t_submit

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def ok(self) -> bool:
        """Completed normally (ERROR-status requests carry the failure in
        finish_reason: "poisoned" / "deadline" / "rejected")."""
        return self.status == FINISHED


class ContinuousBatchingEngine:
    """Slot-pool serving engine. See module docstring.

    `mode`: "distilled" | "cached_conv" (LCSM archs) — non-LCSM archs serve
    their native cache in either setting. `reset_on_evict` zeroes a slot on
    eviction (hygiene / debugging; admission overwrites the slot anyway).

    Fast-path knobs:
      * bucket_prompts — pad prompts to power-of-two buckets (>= min_bucket)
        and prefill up to `max_prefills_per_step` same-bucket requests as one
        fixed-batch call: O(#buckets) prefill executables.
      * prefill_chunk  — prompts longer than this go through resumable
        chunked prefill, one chunk per tick (None disables).
      * overlap        — async host loop: enqueue the next pooled decode
        before fetching the previous tick's tokens.
      * spec_k         — self-speculative decoding: each tick drafts up to
        spec_k tokens per slot with a low-order modal truncation of the
        serving SSM (one fused K-step executable) and verifies them all in
        ONE multi-token step of the full-fidelity model, committing the
        longest accepted prefix + a correction token (serve/speculative.py).
        spec_k="auto" runs a construction-time autotune sweep
        (`speculative.autotune_spec`) that measures candidate
        (spec_k, draft_order, branch) configs against plain decode under a
        saturated workload and adopts the winner — or disables speculation
        when nothing beats plain by `spec_margin`; the report lands in
        `self.spec_report`. `draft_order` sets the draft's real state dim
        (default: half the serving order); `spec_branch >= 2` drafts a
        top-k token tree instead of a chain; `spec_adapt` (default on)
        drives per-slot windows from each request's running acceptance
        (`speculative.SlotSpecController`) — shrinking K, disabling
        speculation per slot, and probing it back on — with per-depth
        compiled executables so a narrow round costs a narrow round.
        `draft_model=(params, cfg)` overrides the draft entirely (testing).
        Requests can opt out per-request (Request.spec).

    Resilience knobs (serve/README.md "Failure handling"): `health_every`
    runs the per-slot state-integrity guard every N ticks (0 disables; the
    default of 2 amortizes the guard's reduction to a few percent of decode
    — corruption is persistent state, so detection slips by at most one
    tick, never escapes);
    `state_margin` scales the pole-derived modal-norm bound; `max_retries` /
    `retry_backoff_ticks` bound quarantine re-prefills before a request
    completes with ERROR status; `demote_spec_after` turns a repeatedly
    quarantined request's speculation off; `demote_engine_after` (opt-in)
    falls the whole distilled engine back to the exact cached-conv path;
    `deadline_s` / `max_queue` give per-request deadlines and bounded-queue
    backpressure; `watchdog_s` flags slow host ticks; `fault_injector`
    (serve/faults.FaultInjector) drives scripted chaos schedules.

    Observability knobs (serve/README.md "Observability"): `metrics` binds
    a serve.metrics.MetricsRegistry (one is created, enabled, when omitted
    — pass MetricsRegistry(enabled=False) to opt out); `tracer` binds a
    serve.trace.Tracer recording host-phase and request-lifecycle spans
    (default: the no-op NULL_TRACER); `events_limit` bounds the recovery-
    event log `self.events` as a ring buffer (None = unbounded,
    `self._events_total` counts everything ever recorded).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 8,
                 max_len: int = 4096, mode: str = "distilled",
                 ctx: ShardCtx = NOCTX, seed: int = 0, mesh=None,
                 max_prefills_per_step: int = 1, reset_on_evict: bool = False,
                 bucket_prompts: bool = True, min_bucket: int = 8,
                 prefill_chunk: Optional[int] = None, overlap: bool = True,
                 spec_k=0, draft_order: Optional[int] = None,
                 spec_branch: int = 1, spec_adapt=True,
                 spec_candidates: Optional[Sequence[Any]] = None,
                 spec_margin: float = 0.05,
                 draft_model: Optional[Tuple[Any, ModelConfig]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 health_every: int = 2, state_margin: float = 1e3,
                 max_retries: int = 2, retry_backoff_ticks: int = 0,
                 demote_spec_after: int = 2,
                 demote_engine_after: Optional[int] = None,
                 drift_check_every: int = 0,
                 drift_tol: Optional[float] = None,
                 deadline_s: Optional[float] = None,
                 max_queue: Optional[int] = None,
                 watchdog_s: Optional[float] = None,
                 fault_injector=None, tracer=None,
                 metrics: Optional[MetricsRegistry] = None,
                 events_limit: Optional[int] = 256):
        if mode not in MODE_LADDER:
            raise ValueError(f"unknown mode {mode!r}")
        if mode in ("cached_conv", "epoch") and cfg.hyena is None:
            raise ValueError(f"{mode} mode requires a Hyena (LCSM) arch")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}"
                             " (None disables chunked prefill)")
        if (prefill_chunk is not None and cfg.ssm is not None
                and prefill_chunk > cfg.ssm.chunk
                and prefill_chunk % cfg.ssm.chunk != 0):
            raise ValueError(
                f"prefill_chunk={prefill_chunk} must divide into the SSD "
                f"chunk length (cfg.ssm.chunk={cfg.ssm.chunk}): use a "
                f"multiple of it, or a value <= it")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.mode = mode
        self.ctx = ctx
        self.max_prefills_per_step = max_prefills_per_step
        self.reset_on_evict = reset_on_evict
        self._bucketed = bucket_prompts
        self._min_bucket = min_bucket
        self._chunk = prefill_chunk
        self._overlap = overlap
        self._prefill_batch = max(1, max_prefills_per_step)
        self._clock = clock
        cache_kind = _MODE_KINDS[mode]
        self._cache_kind = cache_kind
        # --- slot-pool sharding (serve/README.md "Sharded slot pool") ---
        # every per-slot buffer (the pooled cache + the metadata vectors)
        # shards its row axis over the mesh's data axis; each shard decodes
        # its own rows with no communication — the admission scatter and the
        # sampled-token fetch are the only cross-shard hops.
        mesh = self._resolve_mesh(mesh, n_slots)
        self.mesh = mesh
        if mesh is None:
            self._n_shards = 1
            self._slot_sh = None
        else:
            mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
            n_sh = mesh_shape.get("pod", 1) * mesh_shape.get("data", 1)
            if n_sh <= 1:
                raise ValueError("slot-pool mesh has no 'data' axis to "
                                 "shard over (or it has size 1)")
            if n_slots % n_sh != 0:
                raise ValueError(
                    f"n_slots={n_slots} does not divide across {n_sh} slot "
                    f"shards — pick n_slots as a multiple of the data-axis "
                    f"size")
            self._n_shards = n_sh
            self._slot_sh = NamedSharding(mesh, P("data"))
            # params (and later the draft params / long filters) are
            # replicated across the mesh: a committed single-device param
            # tree mixed with a sharded pool in one jit is a placement error
            params = jax.device_put(params, NamedSharding(mesh, P()))
        self.params = params
        # --- observability (serve/README.md "Observability") ---
        # the registry is always present and enabled by default: instrument
        # bumps are plain host-side python mirroring the stats-dict
        # increments; the tracer defaults to the shared no-op. Both are held
        # to <= 2% saturated-decode overhead by the `observability` row in
        # BENCH_serve.json (benchmarks/check_regression.py gate).
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.metrics = MetricsRegistry() if metrics is None else metrics
        _m = self.metrics
        self._mc: Dict[str, Any] = {}    # stats-dict key -> mirror counter
        self._h_tick = _m.histogram("serve_tick_latency_s",
                                    help="host-loop tick latency")
        self._h_ttft = _m.histogram("serve_ttft_s",
                                    help="submit -> first token")
        self._h_latency = _m.histogram(
            "serve_request_latency_s",
            help="submit -> finished, ok requests only")
        self._h_fill = _m.histogram("serve_batch_fill_ratio", RATIO_BUCKETS,
                                    help="active slots / n_slots per tick")
        self._h_spec_win = _m.histogram(
            "serve_spec_window", WINDOW_BUCKETS,
            help="per-slot speculation window at dispatch")
        self._g_queue = _m.gauge("serve_queue_depth")
        self._g_active = _m.gauge("serve_active_slots")
        self._g_shard_occ = [
            _m.gauge(f"serve_shard_occupancy_{s}",
                     help="live slots resident on this mesh shard")
            for s in range(self._n_shards)]
        self._c_finished = _m.counter("serve_requests_finished")
        self._c_errors = _m.counter(
            "serve_requests_error", help="rejected / deadline / poisoned")
        self._c_events = _m.counter(
            "serve_events_total",
            help="recovery-log events (the `events` ring drops the oldest)")
        self.cache, self._cache_sh = self._make_pool(cfg, cache_kind)
        self._draft_sh = None
        self._meta = _jitted("slot_meta", _update_slot_meta,
                             key=self._shard_tag("meta"),
                             **self._vec_out(7))
        # long filters: cached-conv / epoch decode always needs them; chunked
        # prefill needs them for any Hyena layer in every mode
        need_filters = cfg.hyena is not None and (cache_kind in
                                                  ("conv", "epoch")
                                                  or prefill_chunk)
        self._conv_filters = (self._replicate(
            materialize_conv_filters(params, cfg, max_len))
            if cache_kind in ("conv", "epoch") else None)
        self._chunk_filters = (self._conv_filters
                               if cache_kind in ("conv", "epoch")
                               else (self._replicate(
                                   materialize_conv_filters(params, cfg,
                                                            max_len))
                                     if need_filters else None))
        self._build_pool_ops()
        # --- self-speculative decoding (serve/speculative.py) ---
        self.spec_report = None
        if isinstance(spec_k, str):
            if spec_k != "auto":
                raise ValueError(f"spec_k must be an int or 'auto', got "
                                 f"{spec_k!r}")
            from repro.serve import speculative as spec_mod
            self.spec_report = spec_mod.autotune_spec(
                params, cfg, mode=mode, n_slots=n_slots, max_len=max_len,
                ctx=ctx, seed=seed, candidates=spec_candidates,
                margin=spec_margin, draft_model=draft_model)
            ch = self.spec_report.chosen
            spec_k = ch.spec_k if ch is not None else 0
            if ch is not None:
                draft_order = ch.draft_order
                spec_branch = ch.branch
        self._spec_k = int(spec_k)
        self._spec = self._spec_k > 0
        self._spec_branch = int(spec_branch)
        self.draft_cache = None
        # native (distilled) serving: the draft's truncated modes are a
        # subset of the serving state, so the draft reads the serving cache
        # directly (embedded residues) — no second pool, no draft prefill.
        # cached-conv / epoch serving keeps a separate native draft pool:
        # that is the paper's classic pair (exact target, O(d) draft).
        self._draft_shared = cache_kind == "native"
        self._spec_ctl = None
        if self._spec:
            from repro.serve import speculative as spec_mod
            spec_mod.validate_spec_config(cfg, self._spec_k,
                                          branch=self._spec_branch)
            d_ord = (draft_order if draft_order is not None else
                     (cfg.hyena.distill_order // 2 if cfg.hyena else 0))
            self.draft_order = d_ord
            if draft_model is not None:
                self._draft_params, self._draft_cfg = draft_model
                if self._draft_shared and self._draft_cfg is not cfg \
                        and self._draft_cfg != cfg:
                    raise ValueError("shared-state draft requires the draft "
                                     "cfg to match the serving cfg")
            else:
                self._draft_params, self._draft_cfg = \
                    spec_mod.make_draft_params(params, cfg, d_ord,
                                               fit_len=min(max_len, 2048),
                                               embed=self._draft_shared)
            self._draft_params = self._replicate(self._draft_params)
            if not self._draft_shared:
                from repro.serve.engine import (jitted_finalize_prefill,
                                                jitted_prefill,
                                                jitted_prefill_chunk)
                self.draft_cache, self._draft_sh = self._make_pool(
                    self._draft_cfg, "native")
                (self._write_slot_d, self._write_slots_d,
                 self._reset_slot_d) = self._pool_write_ops(
                    self._draft_cfg, "native", self._draft_sh, "draft")
                self._draft_prefill = jitted_prefill(self._draft_cfg,
                                                     max_len, "native", ctx)
                if prefill_chunk:
                    self._draft_prefill_chunk = jitted_prefill_chunk(
                        self._draft_cfg, max_len, "native", ctx)
                    self._draft_finalize = jitted_finalize_prefill(
                        self._draft_cfg, max_len, "native")
            # per-depth executables: a controller-shrunk window dispatches
            # the smallest covering depth instead of masking inside the
            # full-K one, so a narrow round costs a narrow round. On a
            # sharded pool each round's outputs are pinned to the pool /
            # slot-vector shardings (same discipline as _build_pool_ops).
            spec_osh = spec_key = None
            if self.mesh is not None:
                s = self._slot_sh
                spec_osh = (self._cache_sh,
                            None if self._draft_shared else self._draft_sh,
                            s, s, s, s)
                spec_key = (self.mesh, cache_kind)
            self._spec_levels = spec_mod.spec_round_levels(self._spec_k)
            self._spec_rounds = {
                L: spec_mod.jitted_spec_round(cfg, self._draft_cfg, L,
                                              self._draft_shared, ctx,
                                              branch=self._spec_branch,
                                              out_shardings=spec_osh,
                                              shard_key=spec_key)
                for L in self._spec_levels}
            self._spec_round = self._spec_rounds[self._spec_k]
            if spec_adapt:
                # spec_adapt may be a SpecControllerConfig to override the
                # control-law knobs (tests shrink probe_every/min_rounds)
                ctl_cfg = (spec_adapt if isinstance(
                    spec_adapt, spec_mod.SpecControllerConfig) else None)
                self._spec_ctl = spec_mod.SlotSpecController(
                    n_slots, self._spec_k, ctl_cfg, metrics=self.metrics)
        # per-slot host-side bookkeeping; sampling params, last token, PRNG
        # keys, stream counters and speculation windows live on device so the
        # overlapped loop never waits on a host upload
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.active = np.zeros(n_slots, bool)
        self._base_key = jax.random.PRNGKey(seed)
        # sharded pool: every per-slot vector lives row-sharded next to its
        # cache rows (_put_slot_vec is the identity without a mesh)
        self._temps = self._put_slot_vec(jnp.zeros((n_slots,), jnp.float32))
        self._top_ks = self._put_slot_vec(jnp.zeros((n_slots,), jnp.int32))
        self._top_ps = self._put_slot_vec(jnp.ones((n_slots,), jnp.float32))
        self._last = self._put_slot_vec(jnp.zeros((n_slots,), jnp.int32))
        self._slot_keys = self._put_slot_vec(
            jnp.zeros((n_slots,) + self._base_key.shape,
                      self._base_key.dtype))
        self._tok_idx = self._put_slot_vec(jnp.zeros((n_slots,), jnp.int32))
        self._spec_len = self._put_slot_vec(jnp.ones((n_slots,), jnp.int32))
        # host mirror of _spec_len plus a shadow of what the device holds:
        # admission/eviction scatters keep both in sync; controller window
        # changes mark the mirror dirty and _sync_spec_len uploads the whole
        # vector once per change (no per-slot device scatters on the hot
        # path, no recompiles — the executables take spec_len as data)
        self._spec_win = np.ones(n_slots, np.int32)
        self._spec_win_dev = self._spec_win.copy()
        self._admit_sample = _jitted("admit_sample", _admit_sample)
        self._stream_sample = _jitted("stream_sample", _stream_sample,
                                      key=self._shard_tag("stream"),
                                      **self._vec_out(2))
        self._clear_meta = _jitted("clear_slot_meta", _clear_slot_meta,
                                   key=self._shard_tag("clear"),
                                   **self._vec_out(4))
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self._pending: Optional[Tuple[list, Any, Any]] = None
        self._chunk_state: Optional[Dict[str, Any]] = None
        self._buckets_used: set = set()
        self._next_rid = 0
        self.t_admit = 0.0                    # host seconds spent admitting
        self.stats: Dict[str, int] = {"admitted": 0, "evicted": 0,
                                      "decode_steps": 0, "prefills": 0,
                                      "prefill_calls": 0, "chunk_steps": 0,
                                      "spec_rounds": 0, "spec_drafted": 0,
                                      "spec_accepted": 0,
                                      "spec_slot_rounds": 0,
                                      "spec_window_syncs": 0}
        # --- resilience layer (see serve/README.md "Failure handling") ---
        self._tick = 0
        self._dispatch_seq = 0     # monotonic dispatch counter (see _retire)
        self._health_every = max(0, int(health_every))
        self._guard = self._health_every > 0
        # pole-derived bound on the modal-state norm: |x| stays under
        # margin/(1-max|λ|) for stable poles; inf disables the norm check
        # (non-hyena archs, cached-conv kind — finiteness-only there)
        self._state_bound = (modal_state_bound(params, cfg,
                                               margin=state_margin)
                             if cache_kind == "native" else float("inf"))
        # decode-path guard is fused into the decode executable (_decode_g);
        # the spec path keeps a separate state-only health dispatch, built
        # alongside the other pool executables in _build_pool_ops (the
        # spec-round executables don't expose their verify logits, and one
        # extra dispatch amortizes over the round's multi-token yield)
        self.max_retries = int(max_retries)
        self._retry_backoff = max(0, int(retry_backoff_ticks))
        self._demote_spec_after = int(demote_spec_after)
        self._demote_engine_after = demote_engine_after
        self._distilled_faults = 0
        # --- drift sentinel (serve/README.md "Exact fallback & drift
        # sentinel") --- every `drift_check_every` ticks one resident slot
        # (rotating cursor) is shadow-decoded a single step through the
        # exact epoch path off the critical path; |log-softmax| divergence
        # beyond `drift_tol` demotes the engine straight to mode="epoch".
        # Only the distilled mode carries distillation error, so the
        # sentinel arms there and disarms after any demotion.
        self._drift_every = max(0, int(drift_check_every))
        self._drift_tol = drift_tol
        self._drift_cursor = 0
        self._drift_last: Optional[float] = None
        self._drift_certificate = None
        self._sentinel = (self._drift_every > 0 and mode == "distilled"
                          and cfg.hyena is not None)
        self._h_drift = _m.histogram(
            "serve_drift_logit_div", DRIFT_BUCKETS,
            help="sentinel max |log-softmax| gap, distilled vs exact path")
        if self._sentinel:
            from repro.serve.engine import (jitted_decode_step,
                                            jitted_prefill)
            self._drift_prefill = jitted_prefill(cfg, max_len, "epoch", ctx)
            # the shadow decode replays ONE gathered row; without pinned
            # out_shardings it takes the plain memo entry, so it never
            # aliases (or recompiles) the pool-pinned decode executable
            self._drift_decode = jitted_decode_step(cfg, ctx)
            self._drift_filters = (
                self._chunk_filters if self._chunk_filters is not None
                else self._replicate(
                    materialize_conv_filters(params, cfg, max_len)))
            self._gather_rows = _jitted("gather_rows", gather_cache_rows,
                                        key=self._shard_tag("drift"))
        self._deadline_s = deadline_s
        self._any_deadline = deadline_s is not None
        self._max_queue = max_queue
        self._watchdog_s = watchdog_s
        self._injector = fault_injector
        self.resilience = ResilienceCounters(registry=self.metrics)
        # recovery-event log: bounded ring (oldest dropped past
        # events_limit; None = unbounded). serve_events_total /
        # _events_total count every event ever recorded, and with a live
        # tracer each event also lands as an instant on the owning
        # request's trace track
        self.events: Deque[Dict[str, Any]] = deque(maxlen=events_limit)
        self._events_total = 0

    # ------------------------------------------------------------------
    # slot-pool sharding (see serve/README.md "Sharded slot pool")
    # ------------------------------------------------------------------
    @staticmethod
    def _resolve_mesh(mesh, n_slots: int):
        """An explicit `mesh` wins. Otherwise REPRO_SLOT_MESH opts the
        engine into sharding from the environment (the CI sharded-serve job
        sets it): "auto" takes every local device, an integer takes that
        many; either shrinks to the largest count that divides n_slots and
        degrades to single-device (None) at 1."""
        if mesh is not None:
            return mesh
        want = os.environ.get("REPRO_SLOT_MESH", "").strip()
        if not want:
            return None
        n = jax.device_count() if want == "auto" else int(want)
        n = min(n, jax.device_count())
        while n > 1 and n_slots % n != 0:
            n -= 1
        if n <= 1:
            return None
        from repro.launch.mesh import make_slot_mesh
        return make_slot_mesh(n)

    def _make_pool(self, cfg: ModelConfig, cache_kind: str):
        """Fresh pooled cache, placed row-sharded on the mesh when one is
        set. Returns (values_tree, shardings_tree-or-None); the shardings
        come from the logical 'slots' axis (sharding.slot_axes + SLOT_RULES)
        resolved against the mesh."""
        vals, axes = unzip(init_cache(cfg, self.n_slots, self.max_len,
                                      cache_kind=cache_kind, per_slot=True))
        if self.mesh is None:
            return vals, None
        sh = tree_shardings(vals, slot_axes(axes), SLOT_RULES, self.mesh)
        return jax.device_put(vals, sh), sh

    def _replicate(self, tree):
        """Pin a tree (params, long filters) replicated across the mesh."""
        if tree is None or self.mesh is None:
            return tree
        return jax.device_put(tree, NamedSharding(self.mesh, P()))

    def _put_slot_vec(self, v):
        """Place a per-slot vector ((n_slots,) or (n_slots, ...)) with the
        pool's row sharding; identity without a mesh."""
        v = jnp.asarray(v)
        return v if self.mesh is None else jax.device_put(v, self._slot_sh)

    def _put_pool(self, tree, shardings):
        """Reload a host-side cache snapshot onto the pool's placement."""
        vals = jax.tree.map(jnp.asarray, tree)
        return vals if shardings is None else jax.device_put(vals, shardings)

    def _shard_tag(self, tag: str):
        return None if self.mesh is None else (self.mesh, tag)

    def _vec_out(self, n: int):
        """out_shardings kwargs pinning n slot-vector outputs (no-op
        without a mesh)."""
        if self.mesh is None:
            return {}
        sh = self._slot_sh if n == 1 else (self._slot_sh,) * n
        return {"out_shardings": sh}

    def _shard_of(self, b: int) -> int:
        """Which mesh shard owns slot row b (P('data') shards the row axis
        in contiguous blocks)."""
        return b * self._n_shards // self.n_slots

    def _pool_write_ops(self, cfg: ModelConfig, cache_kind: str, sh, tag):
        """The three pool-mutating ops (single-row write, batched admission
        write, row reset) for one pool. Sharded pools pin the output to the
        pool's shardings and key the memo per (mesh, cfg, kind, pool) —
        the serving and draft pools have different tree structures, so they
        cannot share one pinned executable."""
        if self.mesh is None:
            return (_jitted("write", write_cache_slot, donate_argnums=(0,)),
                    _jitted("write_many", write_cache_slots,
                            donate_argnums=(0,)),
                    _jitted("reset", reset_cache_slot, donate_argnums=(0,)))
        key = (self.mesh, cfg, cache_kind, tag)
        return (_jitted("write", write_cache_slot, key=key,
                        out_shardings=sh, donate_argnums=(0,)),
                _jitted("write_many", write_cache_slots, key=key,
                        out_shardings=sh, donate_argnums=(0,)),
                _jitted("reset", reset_cache_slot, key=key,
                        out_shardings=sh, donate_argnums=(0,)))

    def _build_pool_ops(self) -> None:
        """(Re)create every executable whose output layout is pinned to the
        serving pool's structure/shardings — at construction, and again when
        a cache-kind demotion (_demote_to_conv) or pool rebuild swaps the
        pool structure. Pinning out_shardings is what keeps a sharded
        steady state at zero recompiles: the decode/spec outputs feed the
        next tick's inputs, so their layout must never drift."""
        from repro.serve.engine import (jitted_decode_step,
                                        jitted_decode_step_guarded,
                                        jitted_finalize_prefill,
                                        jitted_prefill, jitted_prefill_chunk)
        cfg, kind, ctx = self.cfg, self._cache_kind, self.ctx
        sk = None if self.mesh is None else (self.mesh, kind)
        osh = osh_g = None
        if self.mesh is not None:
            osh = (self._cache_sh, self._slot_sh)
            osh_g = (self._cache_sh, self._slot_sh, self._slot_sh)
        self._decode = jitted_decode_step(cfg, ctx, out_shardings=osh,
                                          shard_key=sk)
        self._decode_g = jitted_decode_step_guarded(cfg, ctx,
                                                    out_shardings=osh_g,
                                                    shard_key=sk)
        self._prefill = jitted_prefill(cfg, self.max_len, kind, ctx)
        (self._write_slot, self._write_slots, self._reset_slot) = \
            self._pool_write_ops(cfg, kind, self._cache_sh, "serve")
        self._health_state = _jitted("health_state", _slot_health_state,
                                     key=self._shard_tag("health"),
                                     **self._vec_out(1))
        self._prefill_chunk = (jitted_prefill_chunk(cfg, self.max_len, kind,
                                                    ctx)
                               if self._chunk else None)
        self._finalize = (jitted_finalize_prefill(cfg, self.max_len, kind)
                          if self._chunk else None)

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int,
               sampling: SamplingParams = GREEDY,
               eos_id: Optional[int] = None, rid: Optional[int] = None
               ) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        req = Request(rid=self._next_rid if rid is None else rid,
                      prompt=prompt, max_new_tokens=max_new_tokens,
                      sampling=sampling, eos_id=eos_id)
        self._next_rid = max(self._next_rid, req.rid) + 1
        return self.submit_request(req)

    def submit_request(self, req: Request) -> Request:
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # every conv-carrying block kind in the arch bounds the minimum
        # prompt length (the exact-length prefill tail slice needs >= W-1)
        cfg = self.cfg
        w = max((cfg.hyena.short_conv - 1) if cfg.hyena else 1,
                (cfg.ssm.d_conv - 1) if cfg.ssm else 1,
                (cfg.rglru.d_conv - 1) if cfg.rglru else 1, 1)
        if req.prompt_len < w:
            raise ValueError(f"prompt shorter than the short-conv tail ({w})")
        if req.prompt_len + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request needs {req.prompt_len + req.max_new_tokens} "
                f"positions > max_len={self.max_len}")
        req.t_submit = self._clock()
        if (self._max_queue is not None
                and len(self.queue) >= self._max_queue):
            # bounded-queue admission control: backpressure is an error
            # completion, not an exception — the caller's stream keeps going
            self.resilience.bump("rejected")
            self._record_event("rejected", rid=req.rid)
            self._finish_error(req, "rejected")
            return req
        req.status = QUEUED
        if req.deadline_s is not None:
            self._any_deadline = True
        self.queue.append(req)
        return req

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def n_free(self) -> int:
        return self.n_slots - self.n_active

    @property
    def has_work(self) -> bool:
        return (bool(self.queue) or self.n_active > 0
                or self._pending is not None
                or self._chunk_state is not None)

    def _slot_is_free(self, b: int) -> bool:
        # a slot reserved by an in-flight chunked prefill holds its Request
        # but is not yet active — it must not be handed out again
        return not self.active[b] and self.slots[b] is None

    def _free_slot(self) -> Optional[int]:
        free = self._free_slots_balanced()
        return free[0] if free else None

    def _free_slots_balanced(self) -> List[int]:
        """Free slots, ordered so admissions spread across mesh shards.
        Single-device this is plain ascending order (unchanged behaviour);
        sharded, each pick goes to the least-loaded shard so one shard never
        ends up crunching every live row while the others decode garbage."""
        free = [b for b in range(self.n_slots) if self._slot_is_free(b)]
        if self._n_shards <= 1 or not free:
            return free
        load = [0] * self._n_shards
        for b in range(self.n_slots):
            if not self._slot_is_free(b):
                load[self._shard_of(b)] += 1
        by_shard: Dict[int, List[int]] = {}
        for b in free:
            by_shard.setdefault(self._shard_of(b), []).append(b)
        out: List[int] = []
        while by_shard:
            s = min(by_shard, key=lambda s: (load[s], s))
            out.append(by_shard[s].pop(0))
            load[s] += 1
            if not by_shard[s]:
                del by_shard[s]
        return out

    def _bucket_of(self, L: int) -> int:
        b = max(self._min_bucket, 1 << max(L - 1, 0).bit_length())
        return min(b, self.max_len)

    def _use_chunked(self, L: int) -> bool:
        return self._chunk is not None and L > self._chunk

    def step(self) -> int:
        """One scheduler tick. Overlapped: (1) enqueue the next pooled decode
        (or speculative draft+verify round) from device-resident state,
        (2) retire the PREVIOUS tick's sampled tokens to host (append / EOS /
        eviction), (3) admit queued requests into freed slots — so host
        bookkeeping and prefills overlap the in-flight decode. Synchronous
        (`overlap=False`): admit, then decode and retire in the same tick
        (the original loop). Returns the number of tokens appended to
        requests during this call."""
        self._tick += 1
        tr = self.tracer
        t_step0 = self._clock()
        emitted = 0
        if self._injector is not None:
            with tr.span("faults"):
                self._apply_scheduled_faults()
        if self._sentinel and self._tick % self._drift_every == 0:
            # sentinel sync point: retire the in-flight tick first so the
            # host-side token record matches the at-rest device cache
            with tr.span("drift_check"):
                prev0, self._pending = self._pending, None
                emitted += self._retire(prev0)
                self._drift_check()
        dispatch = self._dispatch_spec if self._spec else self._dispatch_decode
        prev, self._pending = self._pending, None
        if self._overlap and self.n_active > 0:
            with tr.span("dispatch"):
                self._pending = self._safe_dispatch(dispatch)
        with tr.span("retire"):
            emitted += self._retire(prev)
        if self._any_deadline:
            with tr.span("deadline_sweep"):
                self._sweep_deadlines()
        t0 = self._clock()
        work0 = self.stats["prefill_calls"] + self.stats["chunk_steps"]
        with tr.span("admit"):
            emitted += self._admit_phase()
        if self.stats["prefill_calls"] + self.stats["chunk_steps"] > work0:
            # only admission phases that actually prefilled count toward
            # t_admit; note that with the overlapped loop part of this host
            # time still shadows an in-flight device decode, so the derived
            # decode_tok_per_s is an upper bound on pure-decode throughput
            self.t_admit += self._clock() - t0
        if not self._overlap and self.n_active > 0:
            with tr.span("dispatch"):
                pend = self._safe_dispatch(dispatch)
            with tr.span("retire"):
                emitted += self._retire(pend)
        # per-tick telemetry: the tick-latency histogram is what the
        # watchdog reads, so its cost is the one clock call either way
        lat = self._clock() - t_step0
        self._h_tick.observe(lat)
        n_act = self.n_active
        self._g_queue.set(len(self.queue))
        self._g_active.set(n_act)
        self._h_fill.observe(n_act / self.n_slots)
        if self._n_shards > 1:
            occ = [0] * self._n_shards
            for b in np.nonzero(self.active)[0]:
                occ[self._shard_of(int(b))] += 1
            for g, n in zip(self._g_shard_occ, occ):
                g.set(n)
        if self._watchdog_s is not None and lat > self._watchdog_s:
            self.resilience.bump("watchdog_trips")
            self._record_event("watchdog", latency_s=round(lat, 4))
        return emitted

    # ------------------------------------------------------------------
    # resilience: fault application, guarded dispatch, deadlines
    # ------------------------------------------------------------------
    def _record_event(self, kind: str, **detail) -> None:
        self.events.append({"tick": self._tick, "kind": kind, **detail})
        self._events_total += 1
        self._c_events.inc()
        tr = self.tracer
        if tr.enabled:
            # fold the recovery stream into the trace: rid-carrying events
            # land on the request's own track, the rest on the host track
            tr.instant(kind, cat="recovery", rid=detail.get("rid"),
                       tick=self._tick,
                       **{k: v for k, v in detail.items() if k != "rid"})

    def _bump_stat(self, key: str, n: int = 1) -> None:
        """Increment a stats-dict counter and its mirrored registry counter
        (the dict stays the cheap delta the benches take; the registry
        carries the same series as `serve_<key>` for exposition)."""
        self.stats[key] += n
        c = self._mc.get(key)
        if c is None:
            c = self._mc[key] = self.metrics.counter("serve_" + key)
        c.inc(n)

    def _apply_scheduled_faults(self) -> None:
        """Fire this tick's scripted faults (corrupt / drift / expire /
        stall); the "raise" kind fires inside _safe_dispatch so it lands
        exactly where a real dispatch failure would."""
        inj = self._injector
        tick = self._tick
        residents = [b for b in range(self.n_slots) if self.active[b]]
        for e in inj.corruptions(tick):
            b = inj.pick_slot(e, tick, residents)
            if b is None:
                continue
            self.cache = corrupt_cache_slot(self.cache, b, e.where, e.value)
            inj.record(tick, "corrupt", slot=b, where=e.where)
        for e in inj.drifts(tick):
            b = inj.pick_slot(e, tick, residents)
            if b is None:
                continue
            eps = e.value if math.isfinite(e.value) else 0.05
            self.cache = drift_cache_slot(self.cache, b, eps)
            inj.record(tick, "drift", slot=b, eps=eps)
        for e in inj.expirations(tick):
            b = inj.pick_slot(e, tick, residents)
            if b is None or self.slots[b] is None:
                continue
            req = self.slots[b]
            inj.record(tick, "expire", slot=b, rid=req.rid)
            self.resilience.bump("deadline_expiries")
            self._record_event("deadline", rid=req.rid, forced=True)
            self._finish_error(req, "deadline")
        st = inj.stall_s(tick)
        if st > 0:
            time.sleep(st)

    def _safe_dispatch(self, dispatch):
        """Dispatch one tick, absorbing failures. An injected FaultError is
        raised BEFORE the jitted call, so the donated pool buffers are still
        valid and the tick is simply skipped; a genuine in-flight exception
        may have invalidated donated buffers, so the pool is rebuilt and
        every resident recovered from its committed tokens."""
        try:
            if self._injector is not None:
                self._injector.raise_if_scheduled(self._tick)
            return dispatch()
        except FaultError:
            self.resilience.bump("dispatch_faults")
            self._record_event("dispatch_fault", injected=True)
            return None
        except Exception as e:                        # noqa: BLE001
            self.resilience.bump("dispatch_faults")
            self._record_event("dispatch_fault", injected=False,
                              error=repr(e))
            self._rebuild_pool()
            return None

    def _sweep_deadlines(self) -> None:
        """Expire requests past their end-to-end budget (per-request
        deadline_s, falling back to the engine default): queued requests are
        rejected in place, a chunk-in-flight prefill is cancelled, running
        slots are released. All finish with ERROR status."""
        now = self._clock()

        def expired(req: Request) -> bool:
            dl = req.deadline_s if req.deadline_s is not None \
                else self._deadline_s
            return (dl is not None and not math.isnan(req.t_submit)
                    and now - req.t_submit > dl)

        for req in [r for r in self.queue if expired(r)]:
            self.resilience.bump("deadline_expiries")
            self._record_event("deadline", rid=req.rid, where="queued")
            self._finish_error(req, "deadline")
        if self._chunk_state is not None and expired(self._chunk_state["req"]):
            req = self._chunk_state["req"]
            self._chunk_state = None
            self.resilience.bump("deadline_expiries")
            self._record_event("deadline", rid=req.rid, where="prefilling")
            self._finish_error(req, "deadline")
        for b in range(self.n_slots):
            req = self.slots[b]
            if req is not None and req.status == RUNNING and expired(req):
                self.resilience.bump("deadline_expiries")
                self._record_event("deadline", rid=req.rid, where="running")
                self._finish_error(req, "deadline")

    # ------------------------------------------------------------------
    # drift sentinel (serve/README.md "Exact fallback & drift sentinel")
    # ------------------------------------------------------------------
    @property
    def drift_certificate(self):
        """Static distillation-error certificate
        (core.distill.distillation_certificate), computed lazily and
        cached — the bench drift gate compares the sentinel's measured
        divergence against its per-layer tail bounds."""
        if self._drift_certificate is None and self.cfg.hyena is not None:
            from repro.core.distill import distillation_certificate
            self._drift_certificate = distillation_certificate(
                self.params, self.cfg, self.max_len)
        return self._drift_certificate

    def _drift_check(self) -> None:
        """Shadow-verify one resident slot through the exact path: replay
        its prompt + committed tokens through the epoch-kind prefill (the
        TRUE long filter, full causal FFT) and decode the same last token
        once on a gathered copy of its distilled pool row — both produce
        the next-token distribution, so any |log-softmax| gap beyond
        float32 noise is accumulated distillation error or silent state
        corruption. Off the critical path: runs at the sentinel sync point
        (pending already retired, slot caches at rest), touches only a
        copy of the slot row, and costs one 1-row bucketed prefill.
        Divergence beyond `drift_tol` demotes the engine to mode="epoch"
        and re-prefills every resident through the exact path."""
        residents = [b for b in range(self.n_slots)
                     if self.active[b] and self.slots[b] is not None
                     and self.slots[b].status == RUNNING
                     and self.slots[b].tokens]
        if not residents:
            return
        b = residents[self._drift_cursor % len(residents)]
        self._drift_cursor += 1
        req = self.slots[b]
        seq = np.concatenate([req.prompt, np.asarray(req.tokens, np.int32)])
        L = int(len(seq))
        if L > self.max_len:
            return
        bkt = self._bucket_of(L)
        toks = np.zeros((1, bkt), np.int32)
        toks[0, :L] = seq
        _, exact = self._drift_prefill(self.params, jnp.asarray(toks),
                                       lengths=jnp.asarray([L], jnp.int32))
        # distilled side: decode on a host-round-tripped copy of the slot's
        # pool row — the copy keeps the pool out of the decode donation and
        # normalizes placement so the shadow decode holds ONE executable
        row = jax.device_get(self._gather_rows(self.cache,
                                               jnp.asarray([b], jnp.int32)))
        # numpy first: jnp.asarray on a nested python list dispatches a
        # convert_element_type executable; np -> jax is a plain device put
        tok = jnp.asarray(np.asarray([[req.tokens[-1]]], np.int32))
        _, approx = self._drift_decode(self.params, row, tok,
                                       conv_filters=None)
        # device_get whole arrays, index on host: slicing a jax array
        # here would dispatch tiny dynamic_slice/squeeze executables and
        # break the zero-steady-state-compiles guarantee
        e = _log_softmax_np(np.asarray(jax.device_get(exact),
                                       np.float64)[0])
        a = _log_softmax_np(np.asarray(jax.device_get(approx),
                                       np.float64)[0, 0])
        div = float(np.max(np.abs(e - a)))
        if not math.isfinite(div):
            # a NaN/Inf shadow comparison means the distilled row no longer
            # produces a distribution at all — maximal drift, not a skip
            div = float("inf")
        self._drift_last = div
        self._h_drift.observe(div)
        self.resilience.bump("drift_checks")
        if self._drift_tol is not None and div > self._drift_tol:
            self.resilience.bump("drift_alarms")
            self._record_event("drift_alarm", rid=req.rid, slot=b,
                               divergence=round(div, 6))
            self._demote_engine("epoch")

    def run(self) -> List[Request]:
        """Drain queue + residents to completion; returns finished requests."""
        while self.has_work:
            self.step()
        return self.finished

    def warmup(self, prompt_lens: Sequence[int]) -> None:
        """Compile the serving fast path before a timed run: ONE batched
        prefill per prompt-length *bucket* (not per distinct length), the
        chunked-prefill step + finalize when enabled, the pooled decode step,
        the batched sampler, and the slot-scatter ops. Side effect: idle
        slots advance one (ignored) decode position."""
        lens = sorted({int(x) for x in prompt_lens})
        direct = [L for L in lens if not self._use_chunked(L)]
        # host-side request-key derivation (fold_in + stack at admission)
        # compiles tiny executables on first use — warm them here (at every
        # admission-batch width) so the steady state stays at zero XLA
        # compiles in a fresh process
        rk = jax.random.fold_in(self._base_key, 0)
        for width in {1, self._prefill_batch}:
            jnp.stack([rk] * width)
        # eviction-time slot-meta clear (slot n_slots = dropped no-op)
        (self._temps, self._top_ks, self._top_ps, self._spec_len) = \
            self._clear_meta(self._temps, self._top_ks, self._top_ps,
                             self._spec_len, self.n_slots)

        def warm_admission_ops(K: int, logits) -> None:
            # first-token sampler + slot-meta scatter at admission batch size
            # K; slot index n_slots makes every row a dropped no-op
            tj = jnp.zeros((K,), jnp.float32)
            kj = jnp.zeros((K,), jnp.int32)
            pj = jnp.ones((K,), jnp.float32)
            keyvec = jnp.zeros((K,) + self._base_key.shape,
                               self._base_key.dtype)
            toks = self._admit_sample(keyvec, logits, tj, kj, pj)
            (self._temps, self._top_ks, self._top_ps, self._last,
             self._slot_keys, self._tok_idx, self._spec_len) = self._meta(
                self._temps, self._top_ks, self._top_ps, self._last,
                self._slot_keys, self._tok_idx, self._spec_len,
                jnp.full((K,), self.n_slots, jnp.int32), tj, kj, pj, toks,
                keyvec, jnp.ones((K,), jnp.int32), jnp.ones((K,), jnp.int32))

        if self._bucketed:
            K = self._prefill_batch
            for bkt in sorted({self._bucket_of(L) for L in direct}):
                cache1, logits = self._prefill(
                    self.params, jnp.zeros((K, bkt), jnp.int32),
                    lengths=jnp.full((K,), bkt, jnp.int32))
                # dummy scatter (slot index n_slots drops every row)
                self.cache = self._write_slots(
                    self.cache, cache1, jnp.full((K,), self.n_slots,
                                                 jnp.int32))
                if self._spec and not self._draft_shared:
                    dc1, _ = self._draft_prefill(
                        self._draft_params, jnp.zeros((K, bkt), jnp.int32),
                        lengths=jnp.full((K,), bkt, jnp.int32))
                    self.draft_cache = self._write_slots_d(
                        self.draft_cache, dc1,
                        jnp.full((K,), self.n_slots, jnp.int32))
                warm_admission_ops(K, logits)
                self._buckets_used.add(bkt)
        else:
            for L in direct:
                _, logits = self._prefill(self.params,
                                          jnp.zeros((1, L), jnp.int32))
                if self._spec and not self._draft_shared:
                    self._draft_prefill(self._draft_params,
                                        jnp.zeros((1, L), jnp.int32))
                warm_admission_ops(1, logits)
        if self._chunk is not None and any(self._use_chunked(L) for L in lens):
            pc = self._new_prefill_cache()
            pc, logits = self._prefill_chunk(
                self.params, pc, jnp.zeros((1, self._chunk), jnp.int32), 0,
                chunk_len=self._chunk, conv_filters=self._chunk_filters)
            dc = self._finalize(pc, self._chunk)
            # write + reset slot 0 (free at warmup time) to warm both ops
            self.cache = self._write_slot(self.cache, dc, 0)
            self.cache = self._reset_slot(self.cache, 0)
            if self._spec and not self._draft_shared:
                dpc = self._new_draft_prefill_cache()
                dpc, _ = self._draft_prefill_chunk(
                    self._draft_params, dpc,
                    jnp.zeros((1, self._chunk), jnp.int32), 0,
                    chunk_len=self._chunk, conv_filters=self._chunk_filters)
                ddc = self._draft_finalize(dpc, self._chunk)
                self.draft_cache = self._write_slot_d(self.draft_cache,
                                                      ddc, 0)
                self.draft_cache = self._reset_slot_d(self.draft_cache, 0)
            warm_admission_ops(1, logits)
        if self._spec:
            # one speculative round (fused draft scan + verify/commit) per
            # compiled depth level, so a controller-shrunk window never
            # compiles mid-run; slots are all idle here, so the garbage
            # advance is ignored exactly like the plain-decode warm tick
            for L in self._spec_levels:
                (self.cache, new_draft, _, _, self._last, self._tok_idx) = \
                    self._spec_rounds[L](
                        self.params, self._draft_params, self.cache,
                        self._last, self._spec_len,
                        None if self._draft_shared else self.draft_cache,
                        temperature=self._temps, top_k=self._top_ks,
                        top_p=self._top_ps, slot_keys=self._slot_keys,
                        tok_idx=self._tok_idx,
                        conv_filters=self._conv_filters)
                if not self._draft_shared:
                    self.draft_cache = new_draft
            # the engine falls back to the plain pooled decode whenever no
            # live slot speculates (all windows 1) — warm that path too
            self.cache, logits = self._decode(self.params, self.cache,
                                              self._last[:, None],
                                              conv_filters=self._conv_filters)
            self._stream_sample(self._slot_keys, self._tok_idx,
                                logits[:, 0, :], self._temps, self._top_ks,
                                self._top_ps)
            jax.block_until_ready((self.cache, self.draft_cache))
        else:
            self.cache, logits = self._decode(self.params, self.cache,
                                              self._last[:, None],
                                              conv_filters=self._conv_filters)
            self._stream_sample(self._slot_keys, self._tok_idx,
                                logits[:, 0, :], self._temps, self._top_ks,
                                self._top_ps)
            jax.block_until_ready(self.cache)
        if self._guard:
            # state-integrity guards ride the decode dispatch: warm the
            # fused guarded decode, the spec-path health variant and the
            # quarantine-path slot reset so the steady state stays at zero
            # XLA compiles with guards enabled
            self.cache, logits, h = self._decode_g(
                self.params, self.cache, self._last[:, None],
                self._state_bound, conv_filters=self._conv_filters)
            warm = [h]
            if self._spec:
                warm.append(self._health_state(self.cache, self._state_bound))
            self.cache = self._reset_slot(self.cache, 0)    # idle at warmup
            jax.block_until_ready(warm)
        if self._sentinel:
            # drift-sentinel dispatches: 1-row epoch-kind prefill at every
            # power-of-two bucket (a resident can be checked at any length
            # up to max_len), plus the row gather + 1-row shadow decode —
            # so a sentinel tick never compiles in the steady state
            bkt = self._min_bucket
            while True:
                bkt = min(bkt, self.max_len)
                self._drift_prefill(self.params,
                                    jnp.zeros((1, bkt), jnp.int32),
                                    lengths=jnp.asarray([bkt], jnp.int32))
                if bkt == self.max_len:
                    break
                bkt <<= 1
            row = jax.device_get(self._gather_rows(
                self.cache, jnp.asarray([0], jnp.int32)))
            _, lg = self._drift_decode(self.params, row,
                                       jnp.zeros((1, 1), jnp.int32),
                                       conv_filters=None)
            jax.block_until_ready(lg)

    def prefill_compile_stats(self) -> Dict[str, Any]:
        """Executable counts backing the O(#buckets) claim. Note the jit memo
        is shared across engines with the same (cfg, max_len, mode), so
        counts are per-configuration, not per-instance."""
        from repro.serve.metrics import jit_cache_size
        out: Dict[str, Any] = {
            "buckets_used": sorted(self._buckets_used),
            "prefill_executables": jit_cache_size(self._prefill),
        }
        if self._prefill_chunk is not None:
            out["chunk_executables"] = jit_cache_size(self._prefill_chunk)
        return out

    # ------------------------------------------------------------------
    # decode: overlapped dispatch / retire
    # ------------------------------------------------------------------
    def _dispatch_decode(self):
        """Enqueue one pooled decode + sample on device state; returns a
        pending record (slot->request snapshot, device token vector) to be
        retired after the NEXT dispatch."""
        self._dispatch_seq += 1
        health = None
        with self.tracer.device_span("decode_step"):
            if self._guard and self._tick % self._health_every == 0:
                # fused variant: the integrity reduction rides the decode
                # executable — no extra host dispatch on the hot path
                self.cache, logits, health = self._decode_g(
                    self.params, self.cache, self._last[:, None],
                    self._state_bound, conv_filters=self._conv_filters)
            else:
                self.cache, logits = self._decode(
                    self.params, self.cache, self._last[:, None],
                    conv_filters=self._conv_filters)
            nxt, self._tok_idx = self._stream_sample(
                self._slot_keys, self._tok_idx, logits[:, 0, :], self._temps,
                self._top_ks, self._top_ps)
        self._last = nxt
        self._bump_stat("decode_steps")
        snapshot = [(int(b), self.slots[b], 1)
                    for b in np.nonzero(self.active)[0]]
        try:
            nxt.copy_to_host_async()           # double-buffered transfer
            if health is not None:
                health.copy_to_host_async()
        except AttributeError:
            pass
        return (self._dispatch_seq, snapshot, nxt, None, health)

    def _sync_spec_len(self) -> None:
        """Upload the per-slot window vector when the controller changed it.
        One whole-vector transfer, no recompile (spec_len is data). The
        upload goes through `_put_slot_vec`, so on a sharded pool each
        device receives only its own row block — a plain `jnp.asarray`
        would land the vector committed to device 0 and force an all-to-one
        layout change inside the next spec round."""
        if not np.array_equal(self._spec_win, self._spec_win_dev):
            self._spec_len = self._put_slot_vec(
                np.asarray(self._spec_win, np.int32))
            self._spec_win_dev[:] = self._spec_win
            self._bump_stat("spec_window_syncs")
            self.resilience.bump("spec_window_syncs")

    def _dispatch_spec(self):
        """Enqueue one speculative round — fused K-step draft scan (on the
        serving cache itself for the shared-state draft, else on the draft
        pool; the scan's advanced state is discarded) + multi-token verify,
        acceptance, rollback and replay — as ONE device dispatch per up to
        window-1 + 1 tokens per slot. The controller picks each slot's
        window first; the round then runs the smallest compiled depth
        covering the widest live window, or falls back to the plain pooled
        decode when no live slot speculates this tick. Drafted-token stats
        are counted HERE, at dispatch — a slot evicted before its round
        retires still spent the draft work (the accounting bug the
        retire-time counter had)."""
        act = np.nonzero(self.active)[0]
        if self._spec_ctl is not None:
            for b in act:
                self._spec_win[b] = self._spec_ctl.on_round(int(b))
        need = int(max((self._spec_win[b] for b in act), default=1)) - 1
        if need <= 0:
            return self._dispatch_decode()
        self._dispatch_seq += 1
        self._sync_spec_len()
        K_r = next(L for L in self._spec_levels if L >= need)
        with self.tracer.device_span("spec_round", depth=K_r):
            (self.cache, new_draft, emitted, n_emit, last, tok_idx) = \
                self._spec_rounds[K_r](
                    self.params, self._draft_params, self.cache,
                    self._last, self._spec_len,
                    None if self._draft_shared else self.draft_cache,
                    temperature=self._temps,
                    top_k=self._top_ks, top_p=self._top_ps,
                    slot_keys=self._slot_keys,
                    tok_idx=self._tok_idx,
                    conv_filters=self._conv_filters)
        if not self._draft_shared:
            self.draft_cache = new_draft
        self._last, self._tok_idx = last, tok_idx
        self._bump_stat("decode_steps")
        self._bump_stat("spec_rounds")
        snapshot = []
        for b in act:
            req = self.slots[b]
            win = int(self._spec_win[b])
            if req is not None and req.spec and win > 1:
                self._bump_stat("spec_drafted", win - 1)
                self._bump_stat("spec_slot_rounds")
                self._h_spec_win.observe(win)
            snapshot.append((int(b), req, win))
        health = None
        if self._guard and self._tick % self._health_every == 0:
            health = self._health_state(self.cache, self._state_bound)
        try:
            emitted.copy_to_host_async()
            n_emit.copy_to_host_async()
            if health is not None:
                health.copy_to_host_async()
        except AttributeError:
            pass
        return (self._dispatch_seq, snapshot, emitted, n_emit, health)

    def _retire(self, pending) -> int:
        """Fetch a dispatched tick's tokens (the only host sync point on the
        decode path) and do the EOS/eviction bookkeeping. Speculative
        pending records carry (emitted (B, C), n_emit (B,)): each slot
        appends its accepted prefix + correction, stopping early on EOS /
        max-tokens eviction (the remaining speculated tokens are dropped,
        exactly as a non-speculative run would never have produced them)."""
        if pending is None:
            return 0
        seq, snapshot, toks_dev, n_emit_dev, health_dev = pending
        toks = np.asarray(toks_dev)
        n_emit = None if n_emit_dev is None else np.asarray(n_emit_dev)
        health = None if health_dev is None else np.asarray(health_dev)
        emitted = 0
        tr = self.tracer
        tr_on = tr.enabled
        for b, req, win in snapshot:
            # slot may have been evicted (and even re-admitted) since this
            # tick was dispatched — its speculative token is dropped (the
            # round's drafted tokens were already counted at dispatch, so
            # the acceptance denominator keeps the wasted work). The
            # admit_seq guard catches the SAME request re-admitted into the
            # same slot by a quarantine recovery: a pending dispatched at or
            # before the re-admission (admit_seq records the dispatch
            # counter at admission time, so this is ordering-exact in both
            # the overlapped and sync loops) must not touch the freshly
            # re-prefilled state with its stale tokens or health verdict.
            if (self.slots[b] is not req or req.status != RUNNING
                    or req.admit_seq >= seq):
                continue
            if health is not None and not bool(health[b]):
                # guard tripped: this tick's token(s) for the slot are
                # poisoned — drop them and quarantine the request (re-prefill
                # from its committed tokens, or error out past max_retries)
                self._quarantine(b, req)
                continue
            if n_emit is None:
                self._append_token(b, int(toks[b]))
                emitted += 1
                if tr_on:
                    tr.instant("decode_tick", cat="decode", rid=req.rid)
                continue
            n = int(n_emit[b])
            applied = 0
            for j in range(n):
                self._append_token(b, int(toks[b, j]))
                applied += 1
                emitted += 1
                if self.slots[b] is not req or req.status != RUNNING:
                    break                      # evicted mid-speculation
            if tr_on and applied:
                tr.instant("spec_round" if win > 1 else "decode_tick",
                           cat="decode", rid=req.rid, emitted=applied)
            if req.spec and win > 1:
                # count only DELIVERED accepted drafts: tokens truncated by
                # an EOS/max-tokens eviction never reached the request. A
                # full delivery ends with the correction token (applied - 1
                # drafts); a truncated one delivered accepted drafts only.
                self._bump_stat("spec_accepted", (applied - 1 if applied == n
                                                  else applied))
                if self._spec_ctl is not None and self.slots[b] is req:
                    # feed the controller the round's raw acceptance (n - 1
                    # of win - 1 drafts accepted, eviction or not); skip if
                    # the request just finished — its slot state is reset
                    self._spec_win[b] = self._spec_ctl.observe(
                        b, win - 1, n - 1)
        return emitted

    # ------------------------------------------------------------------
    # admission: bucketed batches + chunked long prompts
    # ------------------------------------------------------------------
    def _eff_prompt(self, req: Request) -> np.ndarray:
        """The token sequence a (re-)admission must prefill: the prompt,
        plus — for a recovered request — all committed tokens but the last
        (which becomes the slot's `_last` input, exactly the state a
        fault-free run had after emitting it)."""
        if req.tokens:
            return np.concatenate([req.prompt,
                                   np.asarray(req.tokens[:-1], np.int32)])
        return req.prompt

    def _eff_len(self, req: Request) -> int:
        return req.prompt_len + max(0, len(req.tokens) - 1)

    def _eligible(self, req: Request) -> bool:
        return req.retry_at <= self._tick      # quarantine backoff

    def _admit_phase(self) -> int:
        emitted = 0
        budget = self.max_prefills_per_step
        if self._chunk_state is not None and budget > 0:
            emitted += self._advance_chunk()     # one chunk per tick
            budget -= 1
        while budget > 0 and self.queue and self._free_slot() is not None:
            idx = chunked = None
            for i, r in enumerate(self.queue):
                if not self._eligible(r):
                    continue
                if self._use_chunked(self._eff_len(r)):
                    if self._chunk_state is None:
                        idx, chunked = i, True
                        break
                    continue          # long prefill in flight; allow bypass
                idx, chunked = i, False
                break
            if idx is None:
                break
            if chunked:
                req = self._pop_queue([idx])[0]
                self._start_chunked(req, self._free_slot())
                emitted += self._advance_chunk()
                budget -= 1
                continue
            if self._bucketed:
                bkt = self._bucket_of(self._eff_len(self.queue[idx]))
                free = self._free_slots_balanced()
                limit = min(budget, len(free), self._prefill_batch)
                take = []
                for i in range(idx, len(self.queue)):
                    r = self.queue[i]
                    if (self._eligible(r)
                            and not self._use_chunked(self._eff_len(r))
                            and self._bucket_of(self._eff_len(r)) == bkt):
                        take.append(i)
                        if len(take) == limit:
                            break
                reqs = self._pop_queue(take)
                emitted += self._admit_batch(reqs, free[:len(reqs)], bkt)
                budget -= len(reqs)
            else:
                req = self._pop_queue([idx])[0]
                emitted += self._admit_batch([req], [self._free_slot()], None)
                budget -= 1
        return emitted

    def _pop_queue(self, indices: List[int]) -> List[Request]:
        picked = set(indices)
        out = [self.queue[i] for i in indices]
        self.queue = deque(r for i, r in enumerate(self.queue)
                           if i not in picked)
        return out

    def _admit_batch(self, reqs: List[Request], slots: List[int],
                     bucket: Optional[int]) -> int:
        """Prefill `reqs` together and scatter into `slots`. bucket=None is
        the legacy exact-length batch=1 path (bucket_prompts=False)."""
        dspan = self.tracer.device_span("prefill", n=len(reqs),
                                        bucket=bucket or 0)
        if bucket is None:
            with dspan:
                prompt = jnp.asarray(self._eff_prompt(reqs[0]),
                                     jnp.int32)[None]
                cache1, logits = self._prefill(self.params, prompt)
                self.cache = self._write_slot(self.cache, cache1, slots[0])
                if self._spec and not self._draft_shared:
                    dc1, _ = self._draft_prefill(self._draft_params, prompt)
                    self.draft_cache = self._write_slot_d(self.draft_cache,
                                                          dc1, slots[0])
        else:
            with dspan:
                K = self._prefill_batch
                toks = np.zeros((K, bucket), np.int32)
                lens = np.full((K,), bucket, np.int32)     # dummy rows: full
                slot_idx = np.full((K,), self.n_slots,
                                   np.int32)               # dummies drop
                for j, (req, slot) in enumerate(zip(reqs, slots)):
                    ep = self._eff_prompt(req)
                    toks[j, :len(ep)] = ep
                    lens[j] = len(ep)
                    slot_idx[j] = slot
                cache1, logits = self._prefill(self.params, jnp.asarray(toks),
                                               lengths=jnp.asarray(lens))
                self.cache = self._write_slots(self.cache, cache1,
                                               jnp.asarray(slot_idx))
                if self._spec and not self._draft_shared:
                    dc1, _ = self._draft_prefill(self._draft_params,
                                                 jnp.asarray(toks),
                                                 lengths=jnp.asarray(lens))
                    self.draft_cache = self._write_slots_d(
                        self.draft_cache, dc1, jnp.asarray(slot_idx))
            self._buckets_used.add(bucket)
        self._bump_stat("prefills", len(reqs))
        self._bump_stat("prefill_calls")
        return self._register_admissions(reqs, slots, logits)

    def _register_admissions(self, reqs: List[Request], slots: List[int],
                             logits) -> int:
        """Sample first tokens from prefill logits (rows 0..len(reqs)-1 are
        the real requests) with each request's OWN stream-index-0 key, push
        sampling params + PRNG keys + stream counters + last tokens to the
        device slot vectors, and flip host bookkeeping to RUNNING."""
        K = logits.shape[0]
        t = np.zeros(K, np.float32)
        k = np.zeros(K, np.int32)
        p = np.ones(K, np.float32)
        sl = np.full(K, self.n_slots, np.int32)
        slen = np.ones(K, np.int32)
        ti = np.ones(K, np.int32)
        resume = np.zeros(K, bool)         # recovery: committed tokens exist
        last_tok = np.zeros(K, np.int32)
        for j, (req, slot) in enumerate(zip(reqs, slots)):
            sp = req.sampling
            t[j], k[j], p[j] = sp.temperature, sp.top_k, sp.top_p
            sl[j] = slot
            slen[j] = (self._spec_k + 1 if (self._spec and req.spec) else 1)
            if req.tokens:
                # recovered request: the cache was re-prefilled through
                # tokens[:-1]; tokens[-1] is the decode input and the stream
                # counter resumes at len(tokens) — the same per-(slot, index)
                # keys a fault-free run would consume next (bit-exactness)
                resume[j] = True
                last_tok[j] = req.tokens[-1]
                ti[j] = len(req.tokens)
        # per-request key tree roots: fold_in(engine_key, rid) — path- and
        # admission-order-independent, so spec and non-spec runs of the same
        # request set consume identical key streams (see serve/README.md)
        rk = [jax.random.fold_in(self._base_key, req.rid) for req in reqs]
        rk += [self._base_key] * (K - len(reqs))        # dummy rows: dropped
        keyvec = jnp.stack(rk)
        tj, kj, pj = jnp.asarray(t), jnp.asarray(k), jnp.asarray(p)
        toks = self._admit_sample(keyvec, logits, tj, kj, pj)
        if resume.any():
            toks = jnp.where(jnp.asarray(resume), jnp.asarray(last_tok), toks)
        (self._temps, self._top_ks, self._top_ps, self._last,
         self._slot_keys, self._tok_idx, self._spec_len) = self._meta(
            self._temps, self._top_ks, self._top_ps, self._last,
            self._slot_keys, self._tok_idx, self._spec_len,
            jnp.asarray(sl), tj, kj, pj, toks, keyvec,
            jnp.asarray(ti), jnp.asarray(slen))
        toks_h = np.asarray(toks)
        now = self._clock()
        for j, (req, slot) in enumerate(zip(reqs, slots)):
            # host mirror + shadow of the device window vector stay in sync
            # with the _meta scatter above (no upload needed this tick)
            self._spec_win[slot] = slen[j]
            self._spec_win_dev[slot] = slen[j]
            if self._spec_ctl is not None:
                self._spec_ctl.admit(slot,
                                     enabled=bool(self._spec and req.spec))
            req.status = RUNNING
            req.slot = slot
            req.admit_seq = self._dispatch_seq
            if math.isnan(req.t_admitted):
                req.t_admitted = now
            self.slots[slot] = req
            self.active[slot] = True
            self._bump_stat("admitted")
            if resume[j]:
                continue          # recovery: no new token at re-admission
            # first generated token comes from the prefill logits (same
            # convention as GenerationEngine.generate)
            self._append_token(slot, int(toks_h[j]))
        return len(reqs) - int(resume[:len(reqs)].sum())

    # ------------------------------------------------------------------
    # chunked long-prompt admission
    # ------------------------------------------------------------------
    def _new_prefill_cache(self):
        # replicated-committed on a mesh: the chunk step's OUTPUT cache is
        # committed (its inputs carry the mesh), so a fresh scratch cache
        # must be too, or chunk 2 of a long prompt recompiles the step with
        # a committed-pcache signature chunk 1 never saw
        pc, _ = unzip(init_prefill_cache(self.cfg, 1, self.max_len,
                                         chunk=self._chunk,
                                         cache_kind=self._cache_kind))
        return self._replicate(pc)

    def _new_draft_prefill_cache(self):
        pc, _ = unzip(init_prefill_cache(self._draft_cfg, 1, self.max_len,
                                         chunk=self._chunk,
                                         cache_kind="native"))
        return self._replicate(pc)

    def _start_chunked(self, req: Request, slot: int) -> None:
        req.status = PREFILLING
        req.slot = slot
        if math.isnan(req.t_admitted):
            req.t_admitted = self._clock()
        self.slots[slot] = req                  # reserve (not yet active)
        self._chunk_state = {"req": req, "slot": slot,
                             "prompt": self._eff_prompt(req),
                             "pcache": self._new_prefill_cache(),
                             "dcache": (self._new_draft_prefill_cache()
                                        if self._spec
                                        and not self._draft_shared else None),
                             "start": 0}

    def _advance_chunk(self) -> int:
        """Consume one chunk of the in-flight long prompt; on the final chunk
        finalize into the reserved slot and emit the first token. With
        speculation on, the draft pool's chunked prefill advances in
        lockstep (one extra chunk executable per tick)."""
        st = self._chunk_state
        req: Request = st["req"]
        prompt = st["prompt"]                   # eff prompt (recovery-aware)
        plen = int(prompt.shape[0])
        C = self._chunk
        cl = min(C, plen - st["start"])
        buf = np.zeros((1, C), np.int32)
        buf[0, :cl] = prompt[st["start"]:st["start"] + cl]
        with self.tracer.device_span("prefill_chunk", rid=req.rid,
                                     start=st["start"]):
            st["pcache"], last_logits = self._prefill_chunk(
                self.params, st["pcache"], jnp.asarray(buf), st["start"],
                chunk_len=cl, conv_filters=self._chunk_filters)
            if self._spec and not self._draft_shared:
                st["dcache"], _ = self._draft_prefill_chunk(
                    self._draft_params, st["dcache"], jnp.asarray(buf),
                    st["start"], chunk_len=cl,
                    conv_filters=self._chunk_filters)
        st["start"] += cl
        self._bump_stat("chunk_steps")
        if st["start"] < plen:
            return 0
        dcache = self._finalize(st["pcache"], plen)
        slot = st["slot"]
        self.cache = self._write_slot(self.cache, dcache, slot)
        if self._spec and not self._draft_shared:
            ddc = self._draft_finalize(st["dcache"], plen)
            self.draft_cache = self._write_slot_d(self.draft_cache, ddc, slot)
        self._bump_stat("prefills")
        self._bump_stat("prefill_calls")
        self._chunk_state = None
        self.slots[slot] = None                 # _register re-claims it
        return self._register_admissions([req], [slot], last_logits)

    # ------------------------------------------------------------------
    def _append_token(self, slot: int, tok: int) -> None:
        req = self.slots[slot]
        assert req is not None
        if math.isnan(req.t_first_token):
            req.t_first_token = self._clock()
        req.tokens.append(tok)
        if req.eos_id is not None and tok == req.eos_id:
            self._evict(slot, "eos")
        elif len(req.tokens) >= req.max_new_tokens:
            self._evict(slot, "max_tokens")

    def _release_slot(self, slot: int) -> None:
        """Free a slot without finishing its request: host bookkeeping plus
        the device-metadata neutralization every departure needs (a stale
        temperature or speculation window on a dead row would force the slow
        branch of every jnp.all fast path — greedy sampler, full-accept
        commit)."""
        self.slots[slot] = None
        self.active[slot] = False
        (self._temps, self._top_ks, self._top_ps, self._spec_len) = \
            self._clear_meta(self._temps, self._top_ks, self._top_ps,
                             self._spec_len, slot)
        self._spec_win[slot] = 1
        self._spec_win_dev[slot] = 1
        if self._spec_ctl is not None:
            self._spec_ctl.evict(slot)

    def _trace_request(self, req: Request) -> None:
        """Emit the request's lifecycle spans from its own recorded
        timestamps at its terminal transition: queue_wait
        [t_submit, t_admitted], prefill [t_admitted, t_first_token], decode
        [t_first_token, t_finished], plus a `retire` instant. TTFT is
        queue_wait + prefill and end-to-end latency is the full span chain
        — the trace reconstructs the measured numbers exactly, by
        construction. Stages a request never reached (errored while queued
        or prefilling) are simply absent."""
        tr = self.tracer
        if not tr.enabled or math.isnan(req.t_submit):
            return
        rid, t_end = req.rid, req.t_finished
        if math.isnan(req.t_admitted):
            tr.complete("queue_wait", req.t_submit, t_end, rid=rid)
        else:
            tr.complete("queue_wait", req.t_submit, req.t_admitted, rid=rid)
            t_first = req.t_first_token
            if math.isnan(t_first):
                tr.complete("prefill", req.t_admitted, t_end, rid=rid)
            else:
                tr.complete("prefill", req.t_admitted, t_first, rid=rid)
                tr.complete("decode", t_first, t_end, rid=rid,
                            tokens=len(req.tokens))
        tr.instant("retire", rid=rid, ts=t_end, reason=req.finish_reason,
                   status=req.status)

    def _evict(self, slot: int, reason: str) -> None:
        req = self.slots[slot]
        req.status = FINISHED
        req.finish_reason = reason
        req.t_finished = self._clock()
        req.slot = -1
        self._release_slot(slot)
        self._bump_stat("evicted")
        self.finished.append(req)
        self._c_finished.inc()
        if not math.isnan(req.t_submit):
            self._h_latency.observe(req.latency)
            if not math.isnan(req.t_first_token):
                self._h_ttft.observe(req.ttft)
        self._trace_request(req)
        if self.reset_on_evict:
            self.cache = self._reset_slot(self.cache, slot)
            if self._spec and not self._draft_shared:
                self.draft_cache = self._reset_slot_d(self.draft_cache, slot)

    # ------------------------------------------------------------------
    # resilience: quarantine / recovery / degradation
    # ------------------------------------------------------------------
    def _finish_error(self, req: Request, reason: str) -> None:
        """Complete a request with ERROR status from any lifecycle stage
        (queued, prefilling, or running on a slot)."""
        try:
            self.queue.remove(req)
        except ValueError:
            pass
        if 0 <= req.slot < self.n_slots and self.slots[req.slot] is req:
            self._release_slot(req.slot)
            self._bump_stat("evicted")
        req.status = ERROR
        req.finish_reason = reason
        req.t_finished = self._clock()
        req.slot = -1
        self.finished.append(req)
        self._c_errors.inc()
        self._trace_request(req)

    def _requeue_for_recovery(self, req: Request) -> None:
        """Put a (slot-released) request at the FRONT of the queue for exact
        re-prefill from prompt + committed tokens, with linear backoff."""
        req.status = QUEUED
        req.slot = -1
        req.retry_at = self._tick + self._retry_backoff * req.retries
        self.queue.appendleft(req)

    def _quarantine(self, slot: int, req: Request) -> None:
        """A guard flagged this slot: zero the poisoned row, release it, and
        either re-prefill the request exactly from its committed tokens
        (bounded retries with backoff) or — past max_retries — complete it
        with ERROR status. Repeated quarantines demote the request to plain
        decode, and (opt-in) repeated corruption demotes the whole engine
        one rung down the MODE_LADDER (distilled -> cached_conv -> epoch)."""
        self.resilience.bump("health_failures")
        req.retries += 1
        self._record_event("quarantine", rid=req.rid, slot=slot,
                           retries=req.retries)
        self._release_slot(slot)
        self.cache = self._reset_slot(self.cache, slot)
        if self._spec and not self._draft_shared:
            self.draft_cache = self._reset_slot_d(self.draft_cache, slot)
        if self.mode in ("distilled", "cached_conv"):
            self._distilled_faults += 1      # faults since the last demotion
        if req.retries > self.max_retries:
            self.resilience.bump("poisoned")
            self._record_event("poisoned", rid=req.rid)
            self._finish_error(req, "poisoned")
        else:
            if req.spec and req.retries >= self._demote_spec_after:
                req.spec = False
                self.resilience.bump("spec_demotions")
                self._record_event("spec_demotion", rid=req.rid)
            self.resilience.bump("slot_reprefills")
            self._requeue_for_recovery(req)
        if (self._demote_engine_after is not None
                and self.mode in ("distilled", "cached_conv")
                and self._distilled_faults >= self._demote_engine_after):
            nxt = MODE_LADDER[MODE_LADDER.index(self.mode) + 1]
            self._demote_engine(nxt)

    def _rebuild_pool(self) -> None:
        """A dispatch raised mid-flight: the jitted step donates the pool
        buffers, so the old cache may be invalid. Re-initialize the pool(s)
        and recover every resident request from its committed tokens; an
        in-flight chunked prefill restarts from scratch (its request has no
        committed tokens yet)."""
        self.cache, self._cache_sh = self._make_pool(self.cfg,
                                                     self._cache_kind)
        if self.draft_cache is not None:
            self.draft_cache, self._draft_sh = self._make_pool(
                self._draft_cfg, "native")
        self._pending = None
        if self._chunk_state is not None:
            req = self._chunk_state["req"]
            slot = self._chunk_state["slot"]
            self._chunk_state = None
            self.slots[slot] = None
            req.status = QUEUED
            req.slot = -1
            self.queue.appendleft(req)
        for b in range(self.n_slots):
            req = self.slots[b]
            if req is None:
                continue
            req.retries += 1
            self._release_slot(b)
            if req.retries > self.max_retries:
                self.resilience.bump("poisoned")
                self._finish_error(req, "poisoned")
            else:
                self.resilience.bump("slot_reprefills")
                self._requeue_for_recovery(req)
        self._record_event("pool_rebuild")

    def _demote_to_conv(self) -> None:
        self._demote_engine("cached_conv")

    def _demote_engine(self, target: str) -> None:
        """Engine-wide graceful degradation down the MODE_LADDER: repeated
        corruption walks one rung (distilled -> cached_conv -> epoch), a
        drift alarm jumps straight to "epoch" (the FutureFill path serves
        the TRUE filter exactly at amortized near-linear cost, so there is
        no distillation error left to drift). Residents are recovered
        through the normal re-prefill path — through the exact path, for a
        drift demotion; speculation is disabled (the shared-state draft
        read the distilled cache). A one-time recompile of prefill/decode
        for the new kind is the accepted cost of the fallback."""
        if self.cfg.hyena is None or target not in MODE_LADDER:
            return
        if MODE_LADDER.index(target) <= MODE_LADDER.index(self.mode):
            return                             # demotions only walk down
        # drop (don't retire) the in-flight tick: its tokens are uncommitted
        # and every resident is about to re-prefill from committed tokens —
        # retiring here could recursively re-trigger demotion
        self._pending = None
        if self._chunk_state is not None:
            req = self._chunk_state["req"]
            slot = self._chunk_state["slot"]
            self._chunk_state = None
            self.slots[slot] = None
            req.status = QUEUED
            req.slot = -1
            self.queue.appendleft(req)
        for b in range(self.n_slots):
            req = self.slots[b]
            if req is not None:
                self._release_slot(b)
                self.resilience.bump("slot_reprefills")
                self._requeue_for_recovery(req)
        self.mode = target
        kind = _MODE_KINDS[target]
        self._cache_kind = kind
        self.cache, self._cache_sh = self._make_pool(self.cfg, kind)
        self._conv_filters = self._replicate(
            materialize_conv_filters(self.params, self.cfg, self.max_len))
        self._chunk_filters = self._conv_filters
        # the new pool has a different tree structure (and shardings), so
        # every pool-pinned executable is rebuilt for the new cache kind
        self._build_pool_ops()
        self._spec = False
        self._spec_ctl = None
        self.draft_cache = None
        self._state_bound = float("inf")   # exact kinds: finiteness only
        self._distilled_faults = 0
        self._sentinel = False         # only the distilled path can drift
        self.resilience.bump("engine_demotions")
        self._record_event("engine_demotion", to=target)


# ---------------------------------------------------------------------------
# Request-stream workload: Poisson arrivals, mixed prompt lengths.
# ---------------------------------------------------------------------------
def synthesize_request_stream(rng: np.random.Generator, n_requests: int, *,
                              rate: float, prompt_lens: Sequence[int],
                              gen_tokens: Tuple[int, int], vocab: int,
                              sampling: SamplingParams = GREEDY,
                              eos_id: Optional[int] = None
                              ) -> List[Tuple[float, Request]]:
    """(arrival_time_s, Request) pairs: exponential inter-arrival gaps at
    `rate` req/s, prompt lengths drawn from `prompt_lens`, generation lengths
    uniform over [gen_tokens[0], gen_tokens[1]]."""
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.choice(np.asarray(prompt_lens)))
        n_gen = int(rng.integers(gen_tokens[0], gen_tokens[1] + 1))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        out.append((t, Request(rid=rid, prompt=prompt, max_new_tokens=n_gen,
                               sampling=sampling, eos_id=eos_id)))
    return out


def run_request_stream(engine: ContinuousBatchingEngine,
                       stream: Sequence[Tuple[float, Request]],
                       *, clock: Callable[[], float] = time.monotonic
                       ) -> Dict[str, float]:
    """Replay a timed request stream through the engine and report
    tokens/s plus p50/p99 end-to-end and first-token latency."""
    pending = sorted(stream, key=lambda p: p[0])
    t0 = clock()
    i = 0
    while i < len(pending) or engine.has_work:
        now = clock() - t0
        while i < len(pending) and pending[i][0] <= now:
            engine.submit_request(pending[i][1])
            i += 1
        if engine.has_work:
            engine.step()
        elif i < len(pending):
            time.sleep(min(1e-3, max(0.0, pending[i][0] - (clock() - t0))))
    wall = clock() - t0
    done = engine.finished
    # latency percentiles over successful requests only: an error-status
    # completion (rejected / deadline / poisoned) may never have produced a
    # first token and would poison the percentiles with NaN
    ok = [r for r in done if r.ok]
    n_tokens = int(sum(len(r.tokens) for r in done))
    decode_wall = max(wall - engine.t_admit, 1e-9)

    def pcts(hist_name: str, values: List[float]) -> Tuple[float, float]:
        # one source of truth with the live exposition: the engine's
        # registry histogram (what /metrics serves) when it saw these
        # completions; exact numpy over the request list otherwise (registry
        # disabled). Histogram percentiles are bucket-interpolated
        # estimates, clamped to the observed min/max and monotone in q.
        h = engine.metrics.get(hist_name)
        if h is not None and h.count >= len(values) > 0:
            return h.percentile(50), h.percentile(99)
        if not values:
            return math.nan, math.nan
        arr = np.asarray(values)
        return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))

    p50_lat, p99_lat = pcts("serve_request_latency_s",
                            [r.latency for r in ok])
    p50_ttft, p99_ttft = pcts("serve_ttft_s",
                              [r.ttft for r in ok
                               if not math.isnan(r.t_first_token)])
    return {
        "n_requests": len(done),
        "n_ok": len(ok),
        "n_errors": len(done) - len(ok),
        "n_tokens": n_tokens,
        "wall_s": wall,
        "tok_per_s": n_tokens / wall if wall > 0 else float("inf"),
        "decode_tok_per_s": n_tokens / decode_wall,
        "p50_latency_s": p50_lat,
        "p99_latency_s": p99_lat,
        "p50_ttft_s": p50_ttft,
        "p99_ttft_s": p99_ttft,
        "resilience": engine.resilience.snapshot(),
    }


def measure_saturated_decode(engine: ContinuousBatchingEngine, *,
                             prompt_len: int = 32,
                             target_tokens: Optional[int] = None,
                             warmup_ticks: int = 4,
                             max_ticks: int = 10_000,
                             seed: int = 0,
                             clock: Callable[[], float] = time.monotonic
                             ) -> Dict[str, Any]:
    """Steady-state decode throughput with every slot busy.

    The stream benchmark's decode_tok_per_s is arrival-diluted (slots idle
    between Poisson arrivals), which both understates throughput and adds
    enough noise to drown a 30% speculation win. This fills all n_slots with
    long greedy requests, burns `warmup_ticks` to get past compile/admission
    transients, then times pure decode ticks until `target_tokens` have been
    emitted (default 48 per slot). Probes get all the decode headroom
    max_len allows; when that is short (small-max_len engines), warmup and
    target shrink to fit so the window still measures real ticks instead of
    breaking empty on a probe that finished during warmup.

    Returns decode_tok_per_s plus the window's speculation deltas:
    acceptance (None when nothing was drafted) and tokens_per_slot_round.
    """
    rng = np.random.default_rng(seed)
    n_slots = engine.n_slots
    headroom = engine.max_len - prompt_len - 1
    if headroom < 2:
        raise ValueError("prompt_len leaves no decode headroom")
    # the earliest-admitted probe decodes through the other slots' admission
    # ticks and the warmup ticks before the window opens; each tick commits
    # at most spec_k+1 tokens
    burst = (engine._spec_k + 1) if engine._spec else 1
    while warmup_ticks > 1 and \
            headroom - (n_slots - 1 + warmup_ticks) * burst < 4 * burst:
        warmup_ticks -= 1
    avail = headroom - (n_slots - 1 + warmup_ticks) * burst
    if target_tokens is None:
        target_tokens = 48 * n_slots
    if avail > 0:
        target_tokens = min(target_tokens, n_slots * avail)
    probes = []
    for rid in range(n_slots):
        prompt = rng.integers(0, engine.cfg.vocab, size=prompt_len)
        probes.append(Request(
            rid=10_000_000 + rid, prompt=prompt.astype(np.int32),
            max_new_tokens=headroom, sampling=GREEDY))
        engine.submit_request(probes[-1])
    # drain admission (prefill ticks) until all slots are decoding
    ticks = 0
    while int(engine.active.sum()) < n_slots:
        if not engine.has_work or ticks >= max_ticks:
            raise RuntimeError("saturation fill failed")
        engine.step()
        ticks += 1
    for _ in range(warmup_ticks):
        engine.step()
    # count via the probe Request objects: their token lists survive
    # eviction, so a probe hitting max_tokens mid-window still contributes
    base = int(sum(len(r.tokens) for r in probes))
    s0 = dict(engine.stats)
    jax.block_until_ready(engine._last)
    t0 = clock()
    ticks = 0
    tokens = 0
    while tokens < target_tokens and ticks < max_ticks:
        engine.step()
        ticks += 1
        tokens = int(sum(len(r.tokens) for r in probes)) - base
        if int(engine.active.sum()) < n_slots:
            break                               # a probe hit max_tokens
    jax.block_until_ready(engine._last)
    wall = max(clock() - t0, 1e-9)
    drafted = engine.stats["spec_drafted"] - s0.get("spec_drafted", 0)
    accepted = engine.stats["spec_accepted"] - s0.get("spec_accepted", 0)
    rounds = (engine.stats.get("spec_slot_rounds", 0)
              - s0.get("spec_slot_rounds", 0))
    # flush: finish the oversized probe requests so the engine is reusable;
    # the in-flight overlapped tick (if any) only carries tokens for the
    # now-evicted probes, so its pending record is dropped too
    for slot in range(n_slots):
        if engine.slots[slot] is not None:
            engine._evict(slot, "probe_done")
    engine._pending = None
    return {
        "decode_tok_per_s": tokens / wall,
        "tokens": tokens,
        "ticks": ticks,
        "acceptance": (accepted / drafted) if drafted > 0 else None,
        "tokens_per_slot_round": (tokens / rounds) if rounds > 0 else None,
    }
