"""Configuration system for the repro framework.

A single `ModelConfig` dataclass describes every architecture in the pool
(dense / MoE / SSM / hybrid / VLM / audio / LCSM).  Architectures are
registered by id in `REGISTRY` and retrieved with `get_config(arch)`.

Input shapes are registered in `SHAPES`; each (arch x shape) pair defines a
dry-run cell.  `input_specs(cfg, shape)` (in launch/specs.py) materializes
jax.ShapeDtypeStruct stand-ins for every model input.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Layer block kinds
# ---------------------------------------------------------------------------
# Block kinds understood by models/transformer.py. A model is a repeating
# `pattern` of blocks, scanned (n_layers // len(pattern)) times.
ATTN = "attn"              # global causal GQA attention
LOCAL_ATTN = "local_attn"  # sliding-window causal attention
RGLRU = "rglru"            # RecurrentGemma RG-LRU recurrent block
MAMBA2 = "mamba2"          # Mamba-2 SSD block (attention-free)
HYENA = "hyena"            # multi-head Hyena long-convolution block (LCSM)

MLP_DENSE = "dense"        # gated or plain MLP (per `act`)
MLP_MOE = "moe"            # mixture-of-experts MLP


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    # router jitter / z-loss co-efficients used during training
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD configuration."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256           # SSD chunk length
    n_groups: int = 1


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU configuration."""
    d_conv: int = 4
    expand: int = 1            # lru width = expand * d_model (RG uses 1x w/ block width)
    window: int = 2048         # local attention window used by LOCAL_ATTN blocks


@dataclass(frozen=True)
class HyenaConfig:
    """Multi-head Hyena (paper, Sec. 4). heads == d_model -> vanilla Hyena.

    filter_param selects the long-filter parametrization:
      "mlp" — Hyena implicit sine MLP;
      "ssm" — H3-style diagonal SSM (modal form, ssm_state modes): the
              paper's other LCSM family, where distillation reduces to
              model-order reduction (App. E.3).
    """
    n_filter_heads: int = 8        # M: number of tied long filters
    filter_order: int = 64         # width of the implicit filter MLP
    filter_emb: int = 33           # positional-embedding dim fed to filter MLP
    short_conv: int = 3            # explicit short conv width for q,k,v
    sine_freq: float = 4.0         # omega_0 for the siren filter MLP (paper D.1)
    modulate: bool = True          # exponential decay window modulation
    filter_param: str = "mlp"      # mlp | ssm (H3)
    ssm_state: int = 64            # modes of the H3 diagonal-SSM filter
    # distillation deployment
    distill_order: int = 16        # d: SSM state dim after distillation


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio | lcsm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    act: str = "swiglu"              # swiglu | geglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10000.0
    m_rope: bool = False             # Qwen2-VL multimodal RoPE (3 sections)
    m_rope_sections: Tuple[int, ...] = (16, 24, 24)
    tie_embeddings: bool = False
    pattern: Tuple[str, ...] = (ATTN,)       # block kinds, tiled to n_layers
    mlp_kind: str = MLP_DENSE
    window: int = 0                  # sliding window for LOCAL_ATTN
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    hyena: Optional[HyenaConfig] = None
    enc_dec: bool = False            # whisper-style encoder-decoder
    n_enc_layers: int = 0
    frontend: str = "none"           # none | audio_stub | vision_stub
    frontend_len: int = 1500         # number of frontend embeddings (stub)
    logit_softcap: float = 0.0       # gemma-style final logit soft-capping
    dtype: str = "bfloat16"
    max_seq: int = 131072

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def blocks(self) -> Tuple[str, ...]:
        """Full per-layer block list (pattern tiled to n_layers)."""
        reps = -(-self.n_layers // len(self.pattern))
        return tuple((self.pattern * reps)[: self.n_layers])

    @property
    def attention_free(self) -> bool:
        return all(b in (MAMBA2, HYENA, RGLRU) for b in self.blocks)

    @property
    def subquadratic(self) -> bool:
        """True if the arch supports O(1)-state decode at 500k context.

        Pure full-attention archs are quadratic and their KV cache is O(L);
        SSM / hybrid(local-attn) / LCSM-with-distillation archs qualify.
        """
        return all(b in (MAMBA2, HYENA, RGLRU, LOCAL_ATTN) for b in self.blocks)

    def n_params(self) -> int:
        """Analytic parameter count (approximate; embeddings included once)."""
        d, f, V = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        total = V * d                      # embedding
        if not self.tie_embeddings:
            total += V * d                 # unembedding
        per_kind: Dict[str, int] = {}
        for b in self.blocks:
            if b in (ATTN, LOCAL_ATTN):
                p = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                    + (self.n_heads * hd) * d
            elif b == MAMBA2:
                s = self.ssm or SSMConfig()
                di = s.expand * d
                p = d * (2 * di + 2 * s.n_groups * s.d_state) + di * d + di
            elif b == RGLRU:
                r = self.rglru or RGLRUConfig()
                di = r.expand * d
                p = 2 * d * di + di * d + 2 * di
            elif b == HYENA:
                h = self.hyena or HyenaConfig()
                p = 3 * d * d + d * d + h.n_filter_heads * (
                    h.filter_emb * h.filter_order + h.filter_order * h.filter_order
                    + h.filter_order)
            else:
                raise ValueError(b)
            # mlp
            if self.mlp_kind == MLP_MOE:
                assert self.moe is not None
                mlp = self.moe.n_experts * 3 * d * f + d * self.moe.n_experts
            elif self.act in ("swiglu", "geglu"):
                mlp = 3 * d * f
            else:
                mlp = 2 * d * f
            total += p + mlp + 2 * d       # norms
            per_kind[b] = p
        if self.enc_dec:
            # encoder layers: attn + mlp (cross-attn counted in decoder blocks above
            # is omitted from this estimate for simplicity)
            enc = self.n_enc_layers * (4 * d * d + 2 * d * f + 2 * d)
            total += enc
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameter count — differs for MoE."""
        if self.mlp_kind != MLP_MOE:
            return self.n_params()
        assert self.moe is not None
        d, f = self.d_model, self.d_ff
        dense_total = self.n_params()
        inactive = (self.moe.n_experts - self.moe.top_k) * 3 * d * f * self.n_layers
        return dense_total - inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned to the paper; see system prompt)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) dry-run cell is well-defined.

    long_500k needs sub-quadratic attention; pure full-attention archs skip it
    (recorded in DESIGN.md). Encoder-only archs would skip decode, but every
    arch in our pool has a decoder.
    """
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k KV cache is O(L); skipped per assignment"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        # import side-effect registration
        import repro.configs  # noqa
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]()


def list_archs() -> List[str]:
    import repro.configs  # noqa
    return sorted(REGISTRY)


# ---------------------------------------------------------------------------
# Smoke-test reduction: same family, tiny dims.
# ---------------------------------------------------------------------------
def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduce a config to a CPU-runnable size preserving its family/topology."""
    kw: Dict[str, object] = dict(
        n_layers=max(2, len(cfg.pattern)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=16,
        d_ff=128,
        vocab=257,
        max_seq=512,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_experts=4, top_k=2)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32)
    if cfg.rglru is not None:
        kw["rglru"] = RGLRUConfig(d_conv=4, expand=1, window=64)
        kw["window"] = 64
    if cfg.hyena is not None:
        kw["hyena"] = dataclasses.replace(
            cfg.hyena, n_filter_heads=2, filter_order=16, filter_emb=9,
            ssm_state=8, distill_order=8)
    if cfg.enc_dec:
        kw["n_enc_layers"] = 2
    if cfg.frontend != "none":
        kw["frontend_len"] = 16
    if cfg.window:
        kw["window"] = 64
    return cfg.replace(**kw)
