"""GQA attention: full/windowed causal for train & prefill, cached decode.

The quadratic path is a plain einsum formulation XLA fuses well; a Pallas
flash-attention kernel (repro.kernels.flash_attention) can be swapped in via
`use_flash=True` on real TPUs (validated in interpret mode in tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Param
from repro.models.layers import NOCTX, ShardCtx, apply_rope, dense_init


def init_attention(key, d: int, n_heads: int, n_kv: int, hd: int):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d, n_heads, hd), ("embed", "heads", None), in_dim=d),
        "wk": dense_init(kk, (d, n_kv, hd), ("embed", "kv_heads", None), in_dim=d),
        "wv": dense_init(kv, (d, n_kv, hd), ("embed", "kv_heads", None), in_dim=d),
        "wo": dense_init(ko, (n_heads, hd, d), ("heads", None, "embed"),
                         in_dim=n_heads * hd),
    }


def _gqa_scores(q, k):
    """q: (B,S,Hq,hd), k: (B,T,Hkv,hd) -> scores (B,Hkv,G,S,T)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k) / np.sqrt(hd)


def _gqa_out(probs, v):
    """probs: (B,Hkv,G,S,T), v: (B,T,Hkv,hd) -> (B,S,Hq,hd)."""
    B, Hkv, G, S, T = probs.shape
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, Hkv * G, out.shape[-1])


def causal_mask(S: int, T: int, offset: int = 0, window: int = 0):
    """(S, T) boolean mask. offset = index of query 0 within the key axis."""
    qpos = jnp.arange(S)[:, None] + offset
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m


def mha(q, k, v, *, causal=True, offset=0, window=0, ctx: ShardCtx = NOCTX,
        cross=False):
    scores = _gqa_scores(q, k).astype(jnp.float32)
    if causal and not cross:
        m = causal_mask(q.shape[1], k.shape[1], offset, window)
        scores = jnp.where(m[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_out(probs, v)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention: O(S * block) memory instead of O(S^2).
#
# Pure-JAX online-softmax over kv blocks with a scalar-predicate lax.cond that
# skips fully-masked blocks at runtime (the causal upper triangle / outside
# the local window). This is the portable path; the Pallas kernel in
# repro.kernels.flash_attention is the TPU-tuned variant of the same
# algorithm.
# ---------------------------------------------------------------------------
def chunked_mha(q, k, v, *, causal=True, offset=0, window=0, block=1024):
    """q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd). Returns (B,S,Hq,hd)."""
    from repro import flags
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    if flags.DRYRUN_UNROLL:
        # python-loop blocks: exact causal FLOPs, fully visible to
        # cost_analysis (scan bodies are otherwise counted once). Block size
        # balances causal over-compute ((nq+1)/nq) against HLO size.
        blk = int(np.clip(S // 4, 1024, 4096))
        return _chunked_mha_unrolled(q, k, v, causal=causal, offset=offset,
                                     window=window, block=blk)
    qb = min(block, S)
    kb = min(block, T)
    assert S % qb == 0 and T % kb == 0, (S, T, block)
    nq, nk = S // qb, T // kb
    scale = 1.0 / np.sqrt(hd)
    qr = q.reshape(B, nq, qb, Hkv, G, hd)
    kr = k.reshape(B, nk, kb, Hkv, hd)
    vr = v.reshape(B, nk, kb, Hkv, hd)

    def q_block(args):
        qi, qblk = args                                  # (B, qb, Hkv, G, hd)
        m0 = jnp.full((B, Hkv, G, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, hd), jnp.float32)

        def kv_step(carry, j):
            def compute(carry):
                m, l, acc = carry
                kblk = kr[:, j]
                vblk = vr[:, j]
                s = jnp.einsum("bqkgh,btkh->bkgqt", qblk, kblk).astype(
                    jnp.float32) * scale
                qpos = offset + qi * qb + jnp.arange(qb)
                kpos = j * kb + jnp.arange(kb)
                valid = jnp.ones((qb, kb), bool)
                if causal:
                    valid = valid & (kpos[None, :] <= qpos[:, None])
                if window > 0:
                    valid = valid & (kpos[None, :] > qpos[:, None] - window)
                s = jnp.where(valid[None, None, None], s, -jnp.inf)
                mj = jnp.maximum(m, jnp.max(s, axis=-1))
                # guard fully-masked rows
                mj_safe = jnp.where(jnp.isfinite(mj), mj, 0.0)
                p = jnp.exp(s - mj_safe[..., None])
                p = jnp.where(valid[None, None, None], p, 0.0)
                corr = jnp.where(jnp.isfinite(m), jnp.exp(m - mj_safe), 0.0)
                l = l * corr + jnp.sum(p, axis=-1)
                acc = acc * corr[..., None] + jnp.einsum(
                    "bkgqt,btkh->bkgqh", p.astype(q.dtype), vblk).astype(jnp.float32)
                return mj, l, acc

            lo = offset + qi * qb                        # first query position
            hi = offset + qi * qb + qb - 1               # last query position
            needed = jnp.ones((), bool)
            if causal:
                needed = needed & (j * kb <= hi)
            if window > 0:
                needed = needed & ((j + 1) * kb - 1 > lo - window)
            return jax.lax.cond(needed, compute, lambda c: c, carry), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, Hkv, G, qb, hd) -> (B, qb, Hq, hd)
        return jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, qb, Hq, hd)

    outs = jax.lax.map(q_block, (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq, hd).astype(q.dtype)


def _chunked_mha_unrolled(q, k, v, *, causal=True, offset=0, window=0,
                          block=4096):
    """Python-loop flash attention: only causally-needed (i, j) block pairs are
    emitted, so HLO FLOPs match a real blocked causal kernel."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qb = min(block, S)
    kb = min(block, T)
    nq, nk = S // qb, T // kb
    scale = 1.0 / np.sqrt(hd)
    qr = q.reshape(B, nq, qb, Hkv, G, hd)
    kr = k.reshape(B, nk, kb, Hkv, hd)
    vr = v.reshape(B, nk, kb, Hkv, hd)
    outs = []
    for i in range(nq):
        lo = offset + i * qb
        hi = lo + qb - 1
        m = jnp.full((B, Hkv, G, qb), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        acc = jnp.zeros((B, Hkv, G, qb, hd), jnp.float32)
        for j in range(nk):
            if causal and j * kb > hi:
                continue                      # strictly above the diagonal
            if window > 0 and (j + 1) * kb - 1 <= lo - window:
                continue                      # entirely left of the window
            s = jnp.einsum("bqkgh,btkh->bkgqt", qr[:, i], kr[:, j]).astype(
                jnp.float32) * scale
            qpos = lo + jnp.arange(qb)
            kpos = j * kb + jnp.arange(kb)
            valid = jnp.ones((qb, kb), bool)
            if causal:
                valid = valid & (kpos[None, :] <= qpos[:, None])
            if window > 0:
                valid = valid & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(valid[None, None, None], s, -jnp.inf)
            mj = jnp.maximum(m, jnp.max(s, axis=-1))
            mj_safe = jnp.where(jnp.isfinite(mj), mj, 0.0)
            p = jnp.exp(s - mj_safe[..., None])
            p = jnp.where(valid[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - mj_safe), 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(q.dtype), vr[:, j]).astype(jnp.float32)
            m = mj
        out = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, qb, Hq, hd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def attention_block(params, x, positions, cfg, *, window=0, ctx: ShardCtx = NOCTX,
                    cross_kv=None, causal=True, return_kv=False,
                    kv_valid=None):
    """Full-sequence attention (train / prefill). x: (B,S,D).

    kv_valid (B, S) marks each row's real (non-padded) positions for
    bucketed prefill: the k/v returned for the decode cache are zeroed at
    padded positions. The attention itself needs no extra mask — with right
    padding, causality already keeps padded keys away from real queries.
    """
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(x.dtype))
    if cross_kv is None:
        k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"].astype(x.dtype))
        q = apply_rope(q, positions, cfg.rope_theta,
                       cfg.m_rope_sections if cfg.m_rope else None)
        k = apply_rope(k, positions, cfg.rope_theta,
                       cfg.m_rope_sections if cfg.m_rope else None)
    else:
        k, v = cross_kv
    # TP sharding of attention FLOPs: shard heads when they divide the model
    # axis, otherwise fall back to context parallelism (shard q's sequence
    # axis; k/v stay replicated over the model axis and every device computes
    # its own q-rows — works for any head count, e.g. 24-head llama on TP=16).
    model_sz = 1
    if ctx.mesh is not None:
        sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
        model_sz = sizes.get("model", 1)
    if q.shape[2] % max(model_sz, 1) == 0:
        q = ctx.cs(q, ("batch", None, "heads", None))
    else:
        q = ctx.cs(q, ("batch", "qseq", "heads", None))
    k = ctx.cs(k, ("batch", None, "kv_heads", None))
    v = ctx.cs(v, ("batch", None, "kv_heads", None))
    is_causal = causal and cross_kv is None
    if q.shape[1] >= 4096 and q.shape[1] % 1024 == 0 and k.shape[1] % 1024 == 0:
        o = chunked_mha(q, k, v, causal=is_causal, window=window)
    else:
        o = mha(q, k, v, causal=is_causal, window=window, ctx=ctx,
                cross=cross_kv is not None)
    y = jnp.einsum("bsnh,nhd->bsd", o, params["wo"].astype(x.dtype))
    if return_kv:
        if kv_valid is not None:
            k = jnp.where(kv_valid[..., None, None], k, 0)
            v = jnp.where(kv_valid[..., None, None], v, 0)
        return y, (k, v)
    return y


def compute_kv(params, x, positions, cfg):
    """Project k, v only (cross-attention cache construction)."""
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"].astype(x.dtype))
    return k, v


# ---------------------------------------------------------------------------
# Chunked (resumable) prefill: one fixed-size chunk of the prompt at a time
# ---------------------------------------------------------------------------
def attention_prefill_chunk(params, cache, x, positions, start, chunk_len,
                            cfg, *, window=0, ctx: ShardCtx = NOCTX):
    """Consume one prompt chunk x (B, C, D) starting at absolute position
    `start` (traced scalar). cache k/v are full-length LINEAR buffers — even
    for windowed layers, which are re-laid-out into ring form by
    `finalize_prefill_cache`. Positions of the chunk at index >= chunk_len
    are padding: their k/v are written as zeros (and excluded from every
    real query by causality). Returns (cache, y (B, C, D))."""
    B, C, _ = x.shape
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(x.dtype))
    k_new = jnp.einsum("bsd,dnh->bsnh", x, params["wk"].astype(x.dtype))
    v_new = jnp.einsum("bsd,dnh->bsnh", x, params["wv"].astype(x.dtype))
    q = apply_rope(q, positions, cfg.rope_theta,
                   cfg.m_rope_sections if cfg.m_rope else None)
    k_new = apply_rope(k_new, positions, cfg.rope_theta,
                       cfg.m_rope_sections if cfg.m_rope else None)
    valid = (jnp.arange(C) < chunk_len)[None, :, None, None]
    k_new = jnp.where(valid, k_new, 0).astype(cache["k"].dtype)
    v_new = jnp.where(valid, v_new, 0).astype(cache["v"].dtype)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, start, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, start, axis=1)
    # chunk queries against the whole buffer: unfilled keys sit strictly in
    # the causal future of every chunk query, so kpos <= qpos masks them
    y = mha(q, k.astype(q.dtype), v.astype(q.dtype), causal=True,
            offset=start, window=window, ctx=ctx)
    y = jnp.einsum("bsnh,nhd->bsd", y, params["wo"].astype(x.dtype))
    return {"k": k, "v": v}, y


# ---------------------------------------------------------------------------
# Multi-token decode on the decode cache (speculative verify / replay)
# ---------------------------------------------------------------------------
def attention_decode_chunk(params, cache, x, pos, active_len, cfg, *,
                           window=0, ctx: ShardCtx = NOCTX):
    """Consume up to C tokens per slot against the DECODE cache (linear or
    ring layout). x: (B, C, D); pos: (B,) per-slot positions; active_len:
    (B,) — row b consumes only its first active_len tokens: positions at
    index >= active_len leave the k/v buffers (and ring slot_pos) untouched,
    which is what lets a speculative verify be replayed with a shorter
    accepted prefix. Returns (cache, y (B, C, D)) with logits-bearing
    outputs at every position (invalid positions produce garbage that the
    caller masks)."""
    B, C, _ = x.shape
    pos = jnp.asarray(pos, jnp.int32)
    active_len = jnp.asarray(active_len, jnp.int32)
    positions = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (B,C)
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(x.dtype))
    k_new = jnp.einsum("bsd,dnh->bsnh", x, params["wk"].astype(x.dtype))
    v_new = jnp.einsum("bsd,dnh->bsnh", x, params["wv"].astype(x.dtype))
    q = apply_rope(q, positions, cfg.rope_theta,
                   cfg.m_rope_sections if cfg.m_rope else None)
    k_new = apply_rope(k_new, positions, cfg.rope_theta,
                       cfg.m_rope_sections if cfg.m_rope else None)
    ring = "slot_pos" in cache
    size = cache["k"].shape[1]
    # Attention READS the pre-write cache plus the chunk's own keys as a
    # separate segment: scattering first would let a later chunk position's
    # ring write evict a key still inside an earlier position's window
    # (ring size == window), silently truncating that query's context.
    T = cache["k"].shape[1]
    Hkv = cache["k"].shape[2]
    G = q.shape[2] // Hkv
    qg = q.reshape(B, C, Hkv, G, q.shape[-1])
    scale = 1.0 / np.sqrt(q.shape[-1])
    s_old = jnp.einsum("bckgh,btkh->bkgct", qg,
                       cache["k"].astype(q.dtype)).astype(jnp.float32) * scale
    # past-segment mask: only positions strictly BEFORE this chunk (also
    # drops stale rows an evicted occupant left at indices >= pos)
    if ring:
        sp = cache["slot_pos"]                                      # (B, eff)
        m_old = (sp[:, None, :] >= 0) & (sp[:, None, :] < pos[:, None, None])
        if window > 0:
            m_old = m_old & (sp[:, None, :] > positions[:, :, None] - window)
    else:
        kpos = jnp.arange(T, dtype=jnp.int32)
        m_old = kpos[None, None, :] < pos[:, None, None]            # (B,C,T)
        if window > 0:
            m_old = m_old & (kpos[None, None, :] >
                             positions[:, :, None] - window)
    s_old = jnp.where(m_old[:, None, None, :, :], s_old, -1e30)
    # in-chunk segment: key i visible to query c iff i <= c (and in-window).
    # Round-trip through the cache dtype first: the sequential decode path
    # reads these keys back from the (bf16) cache, and greedy identity with
    # it requires matching that precision.
    k_chunk = k_new.astype(cache["k"].dtype)
    v_chunk = v_new.astype(cache["v"].dtype)
    s_new = jnp.einsum("bckgh,bikh->bkgci", qg,
                       k_chunk.astype(q.dtype)).astype(jnp.float32) * scale
    ii = jnp.arange(C, dtype=jnp.int32)
    m_new = ii[None, :] <= ii[:, None]                              # (C, C)
    if window > 0:
        m_new = m_new & (ii[None, :] > ii[:, None] - window)
    s_new = jnp.where(m_new[None, None, None], s_new, -1e30)
    scores = jnp.concatenate([s_old, s_new], axis=-1)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    vv = jnp.concatenate([cache["v"].astype(q.dtype),
                          v_chunk.astype(q.dtype)], axis=1)
    o = jnp.einsum("bkgct,btkh->bckgh", probs, vv)
    o = o.reshape(B, C, Hkv * G, o.shape[-1])
    y = jnp.einsum("bsnh,nhd->bsd", o, params["wo"].astype(x.dtype))

    # per-row write indices; idle rows past the buffer end clamp (linear) or
    # wrap (ring) — both are masked out at read time and fully rewritten
    widx = positions % size if ring else jnp.clip(positions, 0, size - 1)
    valid = jnp.arange(C)[None, :] < active_len[:, None]               # (B,C)
    b = jnp.arange(B)[:, None]

    def scatter(buf, new):
        tgt = (B, C) + buf.shape[2:]
        idx = jnp.broadcast_to(widx.reshape((B, C) + (1,) * (buf.ndim - 2)),
                               tgt)
        cur = jnp.take_along_axis(buf, idx, axis=1)
        sel = jnp.where(valid.reshape((B, C) + (1,) * (buf.ndim - 2)),
                        new.astype(buf.dtype), cur)
        return buf.at[b, widx].set(sel)

    new_cache = {"k": scatter(cache["k"], k_new),
                 "v": scatter(cache["v"], v_new)}
    if ring:
        new_cache["slot_pos"] = scatter(cache["slot_pos"], positions)
    return new_cache, y


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------
def init_kv_cache(batch: int, max_len: int, n_kv: int, hd: int, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, max_len, n_kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, hd), dtype),
    }


def attention_decode(params, cache, x, pos, cfg, *, window=0,
                     ctx: ShardCtx = NOCTX, cross_kv=None):
    """One-token decode. x: (B,1,D); pos: scalar int32 (current index) or a
    per-slot (B,) vector — the continuous-batching engine runs every request
    at its own position within one batched step.

    Two cache layouts:
      * linear  — cache length == max_len, written at `pos`, masked by index.
      * ring    — cache carries "slot_pos" (B, eff) (absolute position per
                  ring slot); used for windowed layers so a 500k-context
                  hybrid keeps an O(window) cache. Written at pos % size,
                  masked by slot_pos.
    """
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(x.dtype))
    positions = pos[:, None] if per_slot else jnp.full((B, 1), pos, jnp.int32)
    ring = cross_kv is None and "slot_pos" in cache
    if cross_kv is None:
        k_new = jnp.einsum("bsd,dnh->bsnh", x, params["wk"].astype(x.dtype))
        v_new = jnp.einsum("bsd,dnh->bsnh", x, params["wv"].astype(x.dtype))
        q = apply_rope(q, positions, cfg.rope_theta,
                       cfg.m_rope_sections if cfg.m_rope else None)
        k_new = apply_rope(k_new, positions, cfg.rope_theta,
                           cfg.m_rope_sections if cfg.m_rope else None)
        size = cache["k"].shape[1]
        if per_slot:
            # per-slot write index: scatter one (k, v) row per batch element.
            # Inactive slots may sit past max_len; clamp — they are masked at
            # the scheduler level and fully overwritten on (re)admission.
            widx = pos % size if ring else jnp.minimum(pos, size - 1)
            b = jnp.arange(B)
            k = cache["k"].at[b, widx].set(k_new[:, 0].astype(cache["k"].dtype))
            v = cache["v"].at[b, widx].set(v_new[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": k, "v": v}
            if ring:
                new_cache["slot_pos"] = cache["slot_pos"].at[b, widx].set(pos)
        else:
            widx = pos % size if ring else pos
            k = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), widx, axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), widx, axis=1)
            new_cache = {"k": k, "v": v}
            if ring:
                new_cache["slot_pos"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["slot_pos"],
                    jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32), widx,
                    axis=1)
    else:
        k, v = cross_kv
        new_cache = {}
    T = k.shape[1]
    scores = _gqa_scores(q, k.astype(q.dtype)).astype(jnp.float32)  # (B,Hkv,G,1,T)
    if cross_kv is None:
        pos_b = pos[:, None] if per_slot else pos          # (B,1) | scalar
        if ring:
            sp = new_cache["slot_pos"]                     # (B, eff)
            valid = (sp >= 0) & (sp <= pos_b)
            if window > 0:
                valid = valid & (sp > pos_b - window)
        else:
            kpos = jnp.arange(T)[None, :]
            valid = jnp.broadcast_to(kpos <= pos_b, (B, T))
            if window > 0:
                valid = valid & (kpos > pos_b - window)
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = _gqa_out(probs, v.astype(q.dtype))
    y = jnp.einsum("bsnh,nhd->bsd", o, params["wo"].astype(x.dtype))
    return new_cache, y
