"""Serving fast path: bucketed batch prefill, chunked (resumable) prefill,
and the async overlapped host loop.

Invariants:
  * bucket-padded prefill (per-row `lengths`) produces caches and last
    logits identical to exact-length prefill, per row, in all three cache
    kinds (distilled modal state, cached-conv kv, attention KV) and for the
    windowed ring layout;
  * chunked prefill (prefill_from_cache -> finalize_prefill_cache) matches
    one-shot prefill, including a final partial chunk that splits the prompt
    mid-bucket;
  * the full engine — bucketing + chunking + overlapped loop — is token-for-
    token identical to sequential generation in all three modes;
  * a mixed-prompt-length run compiles <= #buckets + 1 prefill executables
    (the O(#buckets) claim, asserted via the jit executable cache), and the
    post-warmup steady state triggers no further XLA compilation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ATTN, HYENA, LOCAL_ATTN, HyenaConfig, ModelConfig
from repro.distributed.sharding import unzip
from repro.models.model import (finalize_prefill_cache, init_params,
                                init_prefill_cache, materialize_conv_filters,
                                prefill, prefill_from_cache)
from repro.serve.engine import GenerationEngine
from repro.serve.metrics import count_compiles
from repro.serve.scheduler import ContinuousBatchingEngine

MAX_LEN = 48
PROMPT_LENS = (4, 7, 12, 20, 9)
GEN_LENS = (8, 5, 11, 6, 9)


def _hyena_cfg(name="fastpath-hyena"):
    return ModelConfig(name=name, family="lcsm", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, vocab=64, act="gelu", norm="layernorm",
                       pattern=(HYENA,),
                       hyena=HyenaConfig(n_filter_heads=2, filter_order=16,
                                         filter_emb=9, distill_order=8),
                       max_seq=512, dtype="float32")


def _attn_cfg(name="fastpath-attn", pattern=(ATTN,), window=0):
    return ModelConfig(name=name, family="dense", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                       vocab=64, act="gelu", norm="layernorm",
                       pattern=pattern, window=window, max_seq=512,
                       dtype="float32")


@pytest.fixture(scope="module")
def hyena_model():
    cfg = _hyena_cfg()
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


@pytest.fixture(scope="module")
def attn_model():
    cfg = _attn_cfg()
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _prompts(vocab, lens=PROMPT_LENS, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32) for n in lens]


def _leafdict(tree):
    return {str(k): np.asarray(v)
            for k, v in jax.tree_util.tree_leaves_with_path(tree)}


def _assert_cache_rows_close(got, want, row, msg):
    """Compare slot `row` of a batched cache against row 0 of a batch=1
    cache, leaf by leaf. bf16 leaves (attention kv) get a bf16-ulp
    tolerance; everything else is compared tightly."""
    ga, wa = _leafdict(got["groups"]), _leafdict(want["groups"])
    assert ga.keys() == wa.keys()
    for k in ga:
        g, w = ga[k][:, row], wa[k][:, 0]
        tol = dict(rtol=2e-4, atol=2e-5)
        if g.dtype == np.dtype(jnp.bfloat16) or w.dtype == np.dtype(jnp.bfloat16):
            tol = dict(rtol=1e-2, atol=1e-2)
        np.testing.assert_allclose(g.astype(np.float32), w.astype(np.float32),
                                   err_msg=f"{msg}/{k}", **tol)


# ---------------------------------------------------------------------------
# Bucketed (padded, per-row lengths) prefill == exact prefill
# ---------------------------------------------------------------------------
CASES = [("hyena", "native"), ("hyena", "conv"), ("attn", "native"),
         ("local", "native")]


@pytest.mark.parametrize("arch,kind", CASES)
def test_bucketed_prefill_matches_exact(hyena_model, attn_model, arch, kind):
    if arch == "hyena":
        cfg, params = hyena_model
    elif arch == "attn":
        cfg, params = attn_model
    else:   # windowed ring layout
        cfg = _attn_cfg("fastpath-local", pattern=(LOCAL_ATTN,), window=16)
        params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    lens = [4, 7, 12, 20]
    P = 32
    prompts = _prompts(cfg.vocab, lens)
    toks = np.zeros((len(lens), P), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    got, last = prefill(params, jnp.asarray(toks), cfg, max_len=MAX_LEN,
                        cache_kind=kind, lengths=jnp.asarray(lens))
    assert list(np.asarray(got["pos"])) == lens
    for i, p in enumerate(prompts):
        want, lastE = prefill(params, jnp.asarray(p)[None], cfg,
                              max_len=MAX_LEN, cache_kind=kind)
        np.testing.assert_allclose(np.asarray(last)[i], np.asarray(lastE)[0],
                                   rtol=2e-4, atol=2e-5)
        _assert_cache_rows_close(got, want, i, f"{arch}/{kind}/row{i}")


# ---------------------------------------------------------------------------
# Chunked prefill == exact prefill (boundary splits the prompt mid-bucket)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,kind", CASES)
def test_chunked_prefill_matches_exact(hyena_model, attn_model, arch, kind):
    if arch == "hyena":
        cfg, params = hyena_model
    elif arch == "attn":
        cfg, params = attn_model
    else:
        cfg = _attn_cfg("fastpath-local", pattern=(LOCAL_ATTN,), window=16)
        params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    L, C = 21, 8                       # final chunk is partial (21 = 8+8+5)
    p = _prompts(cfg.vocab, [L])[0]
    filters = (materialize_conv_filters(params, cfg, MAX_LEN)
               if cfg.hyena else None)
    want, lastE = prefill(params, jnp.asarray(p)[None], cfg, max_len=MAX_LEN,
                          cache_kind=kind)
    pc, _ = unzip(init_prefill_cache(cfg, 1, MAX_LEN, chunk=C,
                                     cache_kind=kind))
    start = 0
    while start < L:
        cl = min(C, L - start)
        buf = np.zeros((1, C), np.int32)
        buf[0, :cl] = p[start:start + cl]
        pc, last = prefill_from_cache(params, pc, jnp.asarray(buf), start,
                                      cfg, MAX_LEN, chunk_len=cl,
                                      conv_filters=filters, cache_kind=kind)
        start += cl
    got = finalize_prefill_cache(pc, L, cfg, MAX_LEN, cache_kind=kind)
    assert int(np.asarray(got["pos"])) == L
    np.testing.assert_allclose(np.asarray(last)[0], np.asarray(lastE)[0],
                               rtol=2e-4, atol=2e-5)
    _assert_cache_rows_close(got, want, 0, f"chunked/{arch}/{kind}")


# ---------------------------------------------------------------------------
# Full engine: bucketing + chunking + overlapped loop == sequential
# ---------------------------------------------------------------------------
def _sequential_greedy(cfg, params, prompts, gens, mode):
    eng = GenerationEngine(params, cfg, max_len=MAX_LEN, mode=mode)
    return [np.asarray(eng.generate(jax.random.PRNGKey(1),
                                    jnp.asarray(p)[None], g)[0][0])
            for p, g in zip(prompts, gens)]


@pytest.mark.parametrize("mode,arch", [("distilled", "hyena"),
                                       ("cached_conv", "hyena"),
                                       ("distilled", "attn")])
def test_fastpath_engine_matches_sequential(hyena_model, attn_model, mode,
                                            arch):
    """prefill_chunk=8 routes the 9/12/20-token prompts through resumable
    chunked prefill (crossing chunk boundaries mid-bucket) while 4/7 go
    through the bucketed batch path, all under the overlapped loop — output
    must equal sequential single-request generation, token for token."""
    cfg, params = hyena_model if arch == "hyena" else attn_model
    prompts = _prompts(cfg.vocab)
    want = _sequential_greedy(cfg, params, prompts, GEN_LENS, mode)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode=mode, max_prefills_per_step=2,
                                   prefill_chunk=8, overlap=True)
    eng.warmup(PROMPT_LENS)
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(prompts, GEN_LENS)]
    eng.run()
    for r, w in zip(reqs, want):
        assert r.status == "finished"
        np.testing.assert_array_equal(np.asarray(r.tokens), w)
    assert eng.stats["chunk_steps"] > 0          # long prompts were chunked
    assert eng.stats["prefill_calls"] < eng.stats["prefills"] + \
        eng.stats["chunk_steps"]                 # some admissions batched


# ---------------------------------------------------------------------------
# Compile counts: O(#buckets), not O(#distinct prompt lengths)
# ---------------------------------------------------------------------------
def test_prefill_compiles_at_most_buckets_plus_one():
    """A mixed-prompt-length run (7 distinct lengths, 3 buckets + chunked
    long prompts) compiles <= #buckets + 1 prefill executables, and after
    warmup the serving loop triggers NO further XLA compilation. Uses a
    uniquely-named config: the jit memo is shared per-config across engines,
    so a fresh name isolates the executable counts."""
    cfg = _hyena_cfg("fastpath-compile-count")
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    lens = (4, 5, 7, 9, 12, 15, 20)              # buckets {8, 16} + chunked
    eng = ContinuousBatchingEngine(params, cfg, n_slots=3, max_len=MAX_LEN,
                                   max_prefills_per_step=2, prefill_chunk=16,
                                   overlap=True)
    eng.warmup(lens)
    with count_compiles() as scope:
        for g, p in zip((3, 4, 5, 3, 4, 5, 3), _prompts(cfg.vocab, lens)):
            eng.submit(p, max_new_tokens=g)
        eng.run()
    assert scope.compiles == 0, "steady-state serving must not compile"
    stats = eng.prefill_compile_stats()
    n_buckets = len(stats["buckets_used"])
    assert n_buckets == 2, stats
    assert stats["prefill_executables"] is not None
    assert stats["prefill_executables"] <= n_buckets
    assert stats["prefill_executables"] + stats["chunk_executables"] \
        <= n_buckets + 1
    assert len(eng.finished) == len(lens)
