"""Llama-3.2-3B (small llama3) [hf:meta-llama/Llama-3.2; unverified].

Dense decoder, 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.configs.base import ATTN, ModelConfig, register


@register
def llama3_2_3b() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=128256,
        act="swiglu",
        norm="rmsnorm",
        rope_theta=500_000.0,
        tie_embeddings=True,
        pattern=(ATTN,),
        max_seq=131072,
    )
