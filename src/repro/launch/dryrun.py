"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun

Two artifacts per cell:

  PROOF   — the full model, scanned layer stacks, lowered AND compiled for the
            production mesh (16x16 and 2x16x16). Sharding mismatches, compile
            OOMs, unsupported collectives surface here. memory_analysis comes
            from this compile (scan reuses buffers, so temp sizes are
            realistic).

  COSTS   — XLA's cost_analysis counts while-loop bodies ONCE regardless of
            trip count, so a scanned stack under-reports FLOPs/bytes/
            collectives. We therefore compile the SAME cell at 1 and 2 layer
            groups with every structural loop unrolled, and extrapolate:
                total(n) = base + (n_groups - 1) * (cost_2g - cost_1g)
            The delta isolates one full group including its collectives; the
            base holds embed/logits/optimizer. This is exact for uniform
            stacks (all ours are).
"""
# The placeholder-device count MUST be set before any jax initialization.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED, PAPER_ARCHS, SHAPES, cell_applicable,
                           get_config)
from repro.distributed.sharding import FSDP_RULES, SERVE_RULES, TRAIN_RULES
from repro.launch import specs as S
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.roofline import (analyze, collective_bytes,
                                   fused_memory_bytes, model_flops_for)
from repro.models.layers import ShardCtx
from repro.models.model import decode_step, forward, prefill
from repro.train.train_step import make_train_step


def _build_lowered(cfg, shape, mesh, *, moe_impl: str, remat: str,
                   layout: str = "tp"):
    """Lower the cell's step function for `cfg` on `mesh`."""
    if shape.kind == "train":
        rules = FSDP_RULES if layout == "fsdp" else TRAIN_RULES
        pvals, paxes, pshard = S.abstract_params(cfg, mesh, rules)
        ovals, oaxes, oshard = S.abstract_opt(pvals, paxes, mesh, rules)
        batch, bshard = S.batch_spec(cfg, shape, mesh, rules)
        step_fn = make_train_step(cfg, mesh, rules=rules, moe_impl=moe_impl,
                                  remat=remat)
        jitted = jax.jit(step_fn,
                         in_shardings=(pshard, oshard, bshard, None),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        with mesh:
            return jitted.lower(pvals, ovals, batch,
                                jax.ShapeDtypeStruct((), jnp.int32))
    rules = SERVE_RULES
    ctx = ShardCtx(mesh=mesh, rules=rules)
    pvals, paxes, pshard = S.abstract_params(cfg, mesh, rules)
    # VLM patch embeddings occupy kv-cache positions ahead of the text tokens
    extra = cfg.frontend_len if (cfg.frontend != "none" and not cfg.enc_dec) else 0
    if shape.kind == "prefill":
        ps = S.prompt_spec(cfg, shape, mesh, rules)
        cache_len = shape.seq_len + extra
        cvals, caxes, cshard = S.abstract_cache(cfg, shape.global_batch,
                                                cache_len, mesh, rules)

        def prefill_fn(params, tokens, frontend=None):
            return prefill(params, tokens, cfg, max_len=cache_len, ctx=ctx,
                           frontend=frontend, moe_impl=moe_impl)

        args = [pvals, ps["tokens"][0]]
        in_sh = [pshard, ps["tokens"][1]]
        if "frontend" in ps:
            args.append(ps["frontend"][0])
            in_sh.append(ps["frontend"][1])
        jitted = jax.jit(prefill_fn, in_shardings=tuple(in_sh),
                         out_shardings=(cshard, None))
        with mesh:
            return jitted.lower(*args)
    # decode
    cvals, caxes, cshard = S.abstract_cache(cfg, shape.global_batch,
                                            shape.seq_len + extra, mesh, rules)
    tok, tsh = S.decode_token_spec(shape, mesh, rules)

    def decode_fn(params, cache, tokens):
        return decode_step(params, cache, tokens, cfg, ctx=ctx)

    jitted = jax.jit(decode_fn, in_shardings=(pshard, cshard, tsh),
                     out_shardings=(cshard, None), donate_argnums=(1,))
    with mesh:
        return jitted.lower(pvals, cvals, tok)


def _cost_triple(compiled) -> Tuple[float, float, float, Dict[str, float]]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cb, breakdown = collective_bytes(compiled.as_text())
    return flops, byts, cb, breakdown


def _reduced_cfg(cfg, n_periods: int):
    period = len(cfg.pattern)
    kw = {"n_layers": n_periods * period}
    if cfg.enc_dec:
        kw["n_enc_layers"] = n_periods
    return cfg.replace(**kw)


def prove_cell(arch: str, shape_name: str, *, multi_pod: bool,
               moe_impl: str, remat: str, verbose: bool = True,
               layout: str = "tp") -> Dict:
    """Full model, rolled scans: lower + compile + memory_analysis."""
    from repro import flags
    flags.set_dryrun_unroll(False)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    lowered = _build_lowered(cfg, shape, mesh, moe_impl=moe_impl,
                             remat=remat, layout=layout)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {"argument_size": int(getattr(ma, "argument_size_in_bytes", 0)),
               "output_size": int(getattr(ma, "output_size_in_bytes", 0)),
               "temp_size": int(getattr(ma, "temp_size_in_bytes", 0))}
    except Exception:
        pass
    res = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": "proof", "status": "ok",
           "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
           "memory": mem}
    if verbose:
        print(f"[proof {arch} x {shape_name} x {mesh_name}] OK "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
              f"mem(args/temp)={mem.get('argument_size', 0)/1e9:.2f}/"
              f"{mem.get('temp_size', 0)/1e9:.2f} GB", flush=True)
    return res


def measure_cell(arch: str, shape_name: str, *, moe_impl: str, remat: str,
                 verbose: bool = True, layout: str = "tp") -> Dict:
    """Extrapolated roofline costs on the single-pod mesh (see module doc)."""
    from repro import flags
    flags.set_dryrun_unroll(True)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    mesh = make_production_mesh(multi_pod=False)
    n_chips = mesh.devices.size
    period = len(cfg.pattern)
    n_groups_f = cfg.n_layers / period           # fractional OK (remainders)

    t0 = time.time()
    cost = {}
    for tag, np_ in (("1g", 1), ("2g", 2)):
        c = _reduced_cfg(cfg, np_)
        lowered = _build_lowered(c, shape, mesh, moe_impl=moe_impl,
                                 remat=remat, layout=layout)
        compiled = lowered.compile()
        cost[tag] = _cost_triple(compiled)
    t_measure = time.time() - t0

    f1, b1, c1, bd1 = cost["1g"]
    f2, b2, c2, bd2 = cost["2g"]
    scale = n_groups_f - 1.0
    flops = f1 + scale * max(f2 - f1, 0.0)
    byts = b1 + scale * max(b2 - b1, 0.0)
    coll = c1 + scale * max(c2 - c1, 0.0)
    breakdown = {k: bd1.get(k, 0.0) + scale * max(bd2.get(k, 0.0) - bd1.get(k, 0.0), 0.0)
                 for k in set(bd1) | set(bd2)}

    mf = model_flops_for(cfg, shape) / n_chips
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = byts / HW["hbm_bw"]
    t_mem_fused = fused_memory_bytes(cfg, shape, n_chips) / HW["hbm_bw"]
    t_coll = coll / HW["ici_bw"]
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    t_model = mf / HW["peak_flops_bf16"]
    # "fused" fraction: what a TPU with kernel-level fusion would see —
    # memory term from the analytic traffic model instead of XLA:CPU's
    # unfused operand count.
    t_worst_fused = max(t_compute, t_mem_fused, t_coll, 1e-30)
    res = {
        "arch": arch, "shape": shape_name, "mesh": "16x16", "kind": "costs",
        "status": "ok", "measure_s": round(t_measure, 1),
        "hlo_flops": flops, "hlo_bytes": byts, "coll_bytes": coll,
        "coll_breakdown": breakdown, "model_flops": mf,
        "t_compute_ms": t_compute * 1e3, "t_memory_ms": t_memory * 1e3,
        "t_memory_fused_ms": t_mem_fused * 1e3,
        "t_collective_ms": t_coll * 1e3, "bottleneck": bottleneck,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": t_model / max(max(terms.values()), 1e-30),
        "roofline_fraction_fused": t_model / t_worst_fused,
        "moe_impl": moe_impl, "remat": remat, "layout": layout,
    }
    if verbose:
        print(f"[costs {arch} x {shape_name}] Tc={res['t_compute_ms']:.2f}ms "
              f"Tm={res['t_memory_ms']:.2f}ms (fused {res['t_memory_fused_ms']:.2f}) "
              f"Tcoll={res['t_collective_ms']:.2f}ms "
              f"-> {bottleneck} useful={res['useful_ratio']:.2f} "
              f"roofline={res['roofline_fraction']:.1%} "
              f"(fused {res['roofline_fraction_fused']:.1%}) ({t_measure:.0f}s)",
              flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--mode", choices=["proof", "costs", "full"], default="full")
    ap.add_argument("--moe-impl", choices=["dense", "dropless", "ep"], default="dropless")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    archs = ASSIGNED + ["multihyena-1.3b"] if args.all else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]

    results = []
    failures = 0

    def run(fn, *a, **kw):
        nonlocal failures
        try:
            results.append(fn(*a, **kw))
        except Exception as e:
            failures += 1
            traceback.print_exc()
            results.append({"arch": a[0], "shape": a[1], "status": "FAIL",
                            "where": fn.__name__, **kw_meta(kw),
                            "error": str(e)[:500]})
        if args.out:
            _write(results, args)

    def kw_meta(kw):
        return {"mesh": "2x16x16" if kw.get("multi_pod") else "16x16"}

    for arch in archs:
        for shape in shapes:
            if args.mode in ("costs", "full"):
                run(measure_cell, arch, shape, moe_impl=args.moe_impl,
                    remat=args.remat)
            if args.mode in ("proof", "full"):
                if args.mesh in ("pod", "both"):
                    run(prove_cell, arch, shape, multi_pod=False,
                        moe_impl=args.moe_impl, remat=args.remat)
                if args.mesh in ("multipod", "both"):
                    run(prove_cell, arch, shape, multi_pod=True,
                        moe_impl=args.moe_impl, remat=args.remat)
    print(f"entries: {len(results)}  failures: {failures}")
    raise SystemExit(1 if failures else 0)


def _write(results, args):
    os.makedirs(args.out, exist_ok=True)
    tag = "all" if args.all else f"{args.arch}_{args.shape or 'allshapes'}"
    path = os.path.join(args.out, f"dryrun_{tag}_{args.mode}.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
