"""Fault-tolerant checkpointing (no external deps).

Layout: <dir>/step_<N>/ with one .npy per leaf (paths flattened with '/'
escaped) + manifest.json (treedef, shapes, step). Writes go to a temp dir and
are atomically renamed, so a preemption mid-save never corrupts the latest
checkpoint. Saves can run asynchronously on a background thread (the arrays
are first fetched to host, then the training loop continues). restore() finds
the newest complete step.

On a multi-host cluster each host writes only the shards it owns
(addressable_shards); here (single host) that is the full array.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "::"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True) -> None:
        """Snapshot to host memory synchronously, write (a)synchronously."""
        flat, _ = _flatten_with_paths(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        if blocking:
            self._write(step, host)
        else:
            self.wait()
            self._thread = threading.Thread(target=self._write,
                                            args=(step, host), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Dict[str, np.ndarray]) -> None:
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for k, v in host.items():
            fn = k.replace("/", "_") + ".npy"
            np.save(os.path.join(tmp, fn), v)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(host),
                       "time": time.time()}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None
                ) -> Tuple[Any, Optional[int]]:
        """Restore into the structure of `tree_like` (shardings preserved by
        the caller via device_put). Returns (tree, step) or (tree_like, None)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return tree_like, None
        d = os.path.join(self.dir, f"step_{step:09d}")
        flat, treedef = _flatten_with_paths(tree_like)
        restored = {}
        for k in flat:
            fn = os.path.join(d, k.replace("/", "_") + ".npy")
            restored[k] = np.load(fn)
        leaves = [restored[k] for k in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves), step
