"""AdamW with decoupled weight decay, cosine schedule, global-norm clipping.

Self-contained (no optax dependency). Optimizer state is a pytree matching
the parameter tree, so it shards with the same FSDP rules as the params.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * base_lr + (1 - final_frac) * base_lr * 0.5 * (
            1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, max_norm: Optional[float] = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = jnp.zeros((), jnp.float32)
    if max_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_norm)
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay and p.ndim >= 2:       # no decay on norms/biases
            step = step + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    mu = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(count, mu, nu), {"grad_norm": gnorm}
