"""Fig 1.1: generation throughput across batch sizes.

Transformer (kv cache) vs Hyena cached-conv (Lemma 2.1) vs LaughingHyena
(distilled recurrence). Workload: prompt 128, generate 64.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from benchmarks.models import build, hyena_cfg, transformer_cfg
from repro.serve.engine import CachedConvHyenaEngine, GenerationEngine

T_PROMPT, K_GEN = 128, 64


def _throughput_engine(cfg, params, batch):
    eng = GenerationEngine(params, cfg, max_len=T_PROMPT + K_GEN)
    prompt = jnp.ones((batch, T_PROMPT), jnp.int32)

    def run():
        return eng.generate_scanned(jax.random.PRNGKey(0), prompt, K_GEN)

    dt = timeit(run, warmup=1, iters=3)
    return batch * K_GEN / dt, dt


def _throughput_cached_conv(cfg, params, batch):
    eng = CachedConvHyenaEngine(params, cfg, max_len=T_PROMPT + K_GEN)
    caches = eng.init_caches(batch)
    tok = jnp.ones((batch, 1), jnp.int32)

    def run():
        c = caches
        out = None
        for i in range(K_GEN):
            c, out = eng.step(c, tok, jnp.asarray(T_PROMPT + i, jnp.int32))
        return out

    dt = timeit(run, warmup=1, iters=3)
    return batch * K_GEN / dt, dt


def main(out):
    tcfg = transformer_cfg()
    tparams = build(tcfg)
    hcfg = hyena_cfg()
    hparams = build(hcfg, distill=True)
    for batch in (1, 8, 32):
        tp, dt = _throughput_engine(tcfg, tparams, batch)
        out(row(f"fig1.1/transformer_kv/b{batch}", dt * 1e6,
                f"tok_s={tp:.0f}"))
        tp, dt = _throughput_engine(hcfg, hparams, batch)
        out(row(f"fig1.1/laughinghyena/b{batch}", dt * 1e6, f"tok_s={tp:.0f}"))
        tp, dt = _throughput_cached_conv(hcfg, hparams, batch)
        out(row(f"fig1.1/hyena_cached_conv/b{batch}", dt * 1e6,
                f"tok_s={tp:.0f}"))
