"""Serving telemetry: XLA compile-count tracking.

The bucketed-prefill claim — O(#buckets) prefill executables instead of
O(#distinct prompt lengths) — is asserted, not eyeballed: a process-wide
listener on jax.monitoring's backend-compile event counts every XLA
compilation, and per-callable executable counts come from the jit cache
(`_cache_size`). jax.monitoring has no unregister, so the listener is
installed once and counts monotonically; use `count_compiles()` scopes for
deltas.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class _CompileCounter:
    def __init__(self) -> None:
        self._n = 0
        self._installed = False

    def install(self) -> "_CompileCounter":
        if not self._installed:
            jax.monitoring.register_event_duration_secs_listener(self._on_event)
            self._installed = True
        return self

    def _on_event(self, name: str, duration: float, **kwargs) -> None:
        if name == _COMPILE_EVENT:
            self._n += 1

    @property
    def count(self) -> int:
        return self._n


compile_counter = _CompileCounter()


class CompileScope:
    """Result object of `count_compiles()`: `.compiles` is the number of XLA
    backend compilations that happened inside the scope."""

    def __init__(self) -> None:
        self.compiles: Optional[int] = None


@contextlib.contextmanager
def count_compiles():
    c = compile_counter.install()
    scope = CompileScope()
    start = c.count
    try:
        yield scope
    finally:
        scope.compiles = c.count - start


def jit_cache_size(fn) -> Optional[int]:
    """Number of compiled executables held by a jax.jit-wrapped callable
    (one per distinct input signature). None if the API is unavailable."""
    try:
        return int(fn._cache_size())
    except Exception:
        return None


RESILIENCE_KEYS = (
    "health_failures",      # device health bitvector flagged a slot
    "slot_reprefills",      # quarantined slot re-prefilled from its tokens
    "spec_demotions",       # slot demoted from speculation to plain decode
    "engine_demotions",     # distilled engine demoted to exact cached-conv
    "deadline_expiries",    # request evicted past its deadline
    "rejected",             # admission refused: queue at capacity
    "poisoned",             # request finished with error after max retries
    "dispatch_faults",      # dispatch raised and was recovered
    "watchdog_trips",       # host tick exceeded the watchdog latency
    "checkpoint_saves",
    "checkpoint_restores",
    "spec_window_syncs",    # controller window vector uploaded to the pool
)


class ResilienceCounters:
    """Resettable event counters for the engine's resilience layer. Extra
    (non-standard) keys are allowed so tests / future paths can piggyback;
    `snapshot()` always reports every standard key (zeros included) so
    BENCH_serve.json columns stay stable across runs."""

    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        self._c = {k: 0 for k in RESILIENCE_KEYS}

    def bump(self, key: str, n: int = 1) -> None:
        self._c[key] = self._c.get(key, 0) + int(n)

    def get(self, key: str) -> int:
        return int(self._c.get(key, 0))

    def snapshot(self) -> dict:
        return {k: int(v) for k, v in self._c.items()}

    @property
    def total_faults(self) -> int:
        """Faults the engine absorbed (recovered or degraded gracefully)."""
        return sum(self.get(k) for k in ("health_failures", "dispatch_faults",
                                         "deadline_expiries", "rejected",
                                         "watchdog_trips"))


def speculative_summary(stats, spec_k: Optional[int] = None) -> dict:
    """Acceptance-rate report from an engine's `stats` dict: drafted vs
    accepted counts, the acceptance rate, and the mean emitted tokens per
    speculating (round, slot) — accepted drafts + 1 correction token.
    Rates are None (JSON null) rather than NaN when nothing was drafted.

    Slot-rounds come from the engine's dispatch-time `spec_slot_rounds`
    counter when present — with per-slot adaptive windows the drafted count
    no longer implies the round count. `spec_k` remains as a fallback
    divisor for stats dicts from older runs."""
    drafted = int(stats.get("spec_drafted", 0))
    accepted = int(stats.get("spec_accepted", 0))
    slot_rounds = stats.get("spec_slot_rounds")
    if not slot_rounds:
        slot_rounds = drafted / spec_k if spec_k else 0.0
    return {
        "spec_rounds": int(stats.get("spec_rounds", 0)),
        "spec_drafted": drafted,
        "spec_accepted": accepted,
        "acceptance_rate": accepted / drafted if drafted else None,
        "tokens_per_slot_round": (accepted / slot_rounds + 1.0
                                  if slot_rounds else None),
    }
