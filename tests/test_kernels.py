"""Per-kernel allclose sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.modal_filter.modal_filter import modal_filter_pallas
from repro.kernels.modal_filter.ref import modal_filter_ref
from repro.kernels.ssm_decode.ref import ssm_decode_ref
from repro.kernels.ssm_decode.ssm_decode import ssm_decode_pallas


def _modal_params(key, C, d):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return (jnp.log(jax.random.uniform(k1, (C, d), minval=0.4, maxval=0.97)),
            jax.random.uniform(k2, (C, d), maxval=np.pi),
            jax.random.normal(k3, (C, d)),
            jax.random.normal(k4, (C, d)),
            jax.random.normal(k5, (C,)))


@pytest.mark.parametrize("C,d,L,cb,lb", [
    (8, 4, 512, 8, 128),
    (16, 8, 1024, 8, 512),
    (32, 16, 2048, 16, 256),
    (8, 3, 512, 4, 512),          # odd mode count
])
def test_modal_filter_sweep(C, d, L, cb, lb):
    params = _modal_params(jax.random.PRNGKey(C + d), C, d)
    ref = modal_filter_ref(*params, L)
    out = modal_filter_pallas(*params, L=L, cb=cb, lb=lb, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,C,d,bb,cb", [
    (8, 128, 8, 8, 128),
    (16, 256, 16, 8, 64),
    (4, 64, 4, 4, 64),
    (32, 512, 8, 16, 128),
])
def test_ssm_decode_sweep(B, C, d, bb, cb):
    key = jax.random.PRNGKey(B * C)
    params = _modal_params(key, C, d)
    xr = jax.random.normal(jax.random.PRNGKey(1), (B, C, d))
    xi = jax.random.normal(jax.random.PRNGKey(2), (B, C, d))
    u = jax.random.normal(jax.random.PRNGKey(3), (B, C))
    ref = ssm_decode_ref(xr, xi, u, *params)
    out = ssm_decode_pallas(xr, xi, u, *params, bb=bb, cb=cb, interpret=True)
    for r, o in zip(ref, out):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,hd,window", [
    (2, 256, 4, 2, 64, 0),
    (1, 512, 8, 1, 64, 0),        # MQA
    (2, 256, 4, 4, 128, 0),       # MHA
    (2, 256, 4, 2, 64, 128),      # windowed
])
def test_flash_attention_sweep(B, S, Hq, Hkv, hd, window, dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, Hq, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, hd), dtype)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 interpret=True)
    atol = 2e-6 * S if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=max(atol, 0.05))


def test_flash_attention_noncausal():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 256, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 256, 2, 64))
    ref = flash_attention_ref(q, k, v, causal=False)
    out = flash_attention_pallas(q, k, v, causal=False, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.slow
def test_chunked_mha_matches_ref_paths():
    """The portable chunked path and the unrolled dry-run path agree with the
    dense reference (both window and full causal)."""
    from repro.models.attention import _chunked_mha_unrolled, chunked_mha, mha
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 512, 8, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 512, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 512, 2, 32))
    for w in (0, 128):
        ref = mha(q, k, v, causal=True, window=w)
        c1 = chunked_mha(q, k, v, causal=True, window=w, block=128)
        c2 = _chunked_mha_unrolled(q, k, v, causal=True, window=w, block=128)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(ref), atol=2e-5)
        np.testing.assert_allclose(np.asarray(c2), np.asarray(ref), atol=2e-5)
