"""MultiHyena-153M — the paper's own architecture (Sec. 4 / Sec. 5.1).

LCSM: 18L d_model=864, 8 tied long-convolution filter heads, GPT-ish MLP,
vocab=50304 (GPT-NeoX tokenizer, as in the Hyena/Pile setup of [2]).
This is the model LaughingHyena distillation targets; after distillation
each long filter becomes an order-16 diagonal SSM enabling O(1) decode,
so it runs the long_500k cell.
"""
from repro.configs.base import HYENA, HyenaConfig, ModelConfig, register


@register
def multihyena_153m() -> ModelConfig:
    return ModelConfig(
        name="multihyena-153m",
        family="lcsm",
        n_layers=18,
        d_model=864,
        n_heads=8,            # qkv projection heads == filter heads
        n_kv_heads=8,
        head_dim=108,
        d_ff=3456,
        vocab=50304,
        act="gelu",
        norm="layernorm",
        pattern=(HYENA,),
        hyena=HyenaConfig(n_filter_heads=8, filter_order=64, filter_emb=33,
                          short_conv=3, sine_freq=4.0, distill_order=16),
        tie_embeddings=True,
        max_seq=1_048_576,
    )


@register
def multihyena_1_3b() -> ModelConfig:
    """1.3B MultiHyena used for the paper's throughput headline (Fig 1.1)."""
    return ModelConfig(
        name="multihyena-1.3b",
        family="lcsm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab=50304,
        act="gelu",
        norm="layernorm",
        pattern=(HYENA,),
        hyena=HyenaConfig(n_filter_heads=16, filter_order=64, filter_emb=33,
                          short_conv=3, sine_freq=4.0, distill_order=16),
        tie_embeddings=True,
        max_seq=1_048_576,
    )
