"""Pallas TPU kernel: fused modal-SSM decode step.

The auto-regressive decode step is memory-bound: per token it must stream the
(B, C, d) complex state in and out of HBM once. Unfused XLA emits separate
kernels for the output reduction, the two state-update products and the
add, re-reading the state several times. This kernel performs

    y = Re[R . x] + h0 u ;  x' = lam x + u

in a single pass: one read of (x_re, x_im), one write of (x_re', x_im'), one
read of u and the (C, d) parameters (broadcast across batch blocks).

Grid: (B // bb, C // cb). State tiles (bb, cb, d) live in VMEM; d is the lane
axis (modal orders are small, <= 128), channels the sublane axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_re_ref, x_im_ref, u_ref, log_a_ref, theta_ref, R_re_ref,
            R_im_ref, h0_ref, y_ref, nx_re_ref, nx_im_ref):
    xr = x_re_ref[...]                          # (bb, cb, d)
    xi = x_im_ref[...]
    u = u_ref[...]                              # (bb, cb)
    lr = jnp.exp(log_a_ref[...]) * jnp.cos(theta_ref[...])   # (cb, d)
    li = jnp.exp(log_a_ref[...]) * jnp.sin(theta_ref[...])
    # output first (paper convention: y_t from x_t), then the update
    y = jnp.sum(xr * R_re_ref[...][None] - xi * R_im_ref[...][None], axis=-1)
    y_ref[...] = y + h0_ref[...][None] * u
    nx_re_ref[...] = lr[None] * xr - li[None] * xi + u[..., None]
    nx_im_ref[...] = lr[None] * xi + li[None] * xr


@functools.partial(jax.jit, static_argnames=("bb", "cb", "interpret"))
def ssm_decode_pallas(x_re, x_im, u, log_a, theta, R_re, R_im, h0, *,
                      bb: int = 8, cb: int = 128, interpret: bool = True):
    B, C, d = x_re.shape
    bb = min(bb, B)
    cb = min(cb, C)
    assert B % bb == 0 and C % cb == 0, (B, C, bb, cb)
    grid = (B // bb, C // cb)
    state_spec = pl.BlockSpec((bb, cb, d), lambda bi, ci: (bi, ci, 0))
    param_spec = pl.BlockSpec((cb, d), lambda bi, ci: (ci, 0))
    vec_spec = pl.BlockSpec((bb, cb), lambda bi, ci: (bi, ci))
    f32 = jnp.float32
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[state_spec, state_spec, vec_spec, param_spec, param_spec,
                  param_spec, param_spec,
                  pl.BlockSpec((cb,), lambda bi, ci: (ci,))],
        out_specs=[vec_spec, state_spec, state_spec],
        out_shape=[jax.ShapeDtypeStruct((B, C), f32),
                   jax.ShapeDtypeStruct((B, C, d), f32),
                   jax.ShapeDtypeStruct((B, C, d), f32)],
        interpret=interpret,
    )(x_re.astype(f32), x_im.astype(f32), u.astype(f32),
      log_a.astype(f32), theta.astype(f32), R_re.astype(f32),
      R_im.astype(f32), h0.astype(f32))
    return tuple(out)
