"""Serving-benchmark regression gate.

Compares a fresh `make bench-serve` run against the committed baseline
(BENCH_serve.json at the repo root) and fails if any serve_stream mode's
throughput dropped by more than the threshold (default 15%).

Speculation gate: the `distilled_spec` mode must keep up with plain
`distilled` decode *in the same new run* — `--spec-ratio` (default 1.0)
times the plain decode tok/s, compared on the saturated-decode metric
(`decode_sat_tok_per_s`: all slots busy, pure decode ticks) with a fallback
to the arrival-diluted stream `decode_tok_per_s` for files that predate it.
A baseline-relative spec floor would silently ratchet whatever number is
committed (the gate that let a 534-vs-990 regression pass); the same-run
comparison can't: the autotuner may disable speculation per slot or
entirely, but the mode must never trail plain decoding.

  PYTHONPATH=src python -m benchmarks.check_regression \
      --baseline BENCH_baseline.json --new BENCH_serve.json

Chaos gate (`--chaos BENCH_chaos.json`, from `make bench-chaos`): every mode
run under the standard fault schedule must have brought every submitted
request to a terminal status — finishing with an error status after bounded
retries (poisoned / deadline / rejected) counts as graceful degradation and
passes; a request that never completed (or a mode that crashed out of the
bench entirely) fails. Recovered-fault counters (quarantines, re-prefills,
dispatch faults, watchdog trips) are reported in the summary table but not
gated. `--chaos` can run standalone, without `--baseline`.

Scaling gate: the sharded-slot-pool device sweep (`serve_stream.scaling`)
must have produced every row (no errored subprocess) with zero steady-state
compiles; throughputs are threshold-compared per device count only when the
baseline carries the same row, so baselines predating the sweep gate
nothing and never fail.

Observability gate: the `serve_stream.observability` row measures saturated
decode with the telemetry layer (span tracer + metrics registry) fully on
vs fully off in the same run; the on side must stay within `--obs-overhead`
(default 2%) of the off side with zero steady-state compiles. Same-run
ratio, so it is machine-independent like the spec gate; bench files
predating the row are skipped, not failed.

Drift gate (`--drift`): the `serve_stream.error_vs_length` row measures the
distilled path's teacher-forced next-token divergence from the exact
epoched-FFT path at growing horizons; every measured point must stay within
`--drift-scale` (default 1.0) times the static truncation certificate
(`distillation_certificate` total l1 — the bound is an upper bound, so
scale 1.0 just asserts the certificate holds at the logits). The
`serve_stream.sentinel` row must keep the drift sentinel's saturated-decode
overhead within `--obs-overhead` with zero steady-state compiles. The chaos
`distilled_drift` row must show at least one sentinel alarm and a final
mode of `epoch` (detection + demotion actually happened). Files predating
the rows are skipped unless `--drift` was passed explicitly.

A markdown comparison table (old -> new tok/s per mode, acceptance, tokens
per round) is appended to `--summary` when given, else to the file named by
$GITHUB_STEP_SUMMARY when set — so spec perf is visible on every PR's
Actions page without downloading the artifact.

CI runs this with the committed file as baseline (copied aside before the
bench overwrites it). Old baselines that emitted counts as floats (16.0)
are tolerated.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional


def _modes(doc) -> Dict[str, Dict[str, Any]]:
    return doc.get("serve_stream", {}).get("modes", {})


def _scaling(doc) -> Dict[int, Dict[str, Any]]:
    """Device-sweep rows keyed by device count. Empty for files that
    predate the sharded slot pool — callers must not fail on those."""
    rows = doc.get("serve_stream", {}).get("scaling", {}).get("devices", [])
    out: Dict[int, Dict[str, Any]] = {}
    for r in rows:
        try:
            out[int(r["devices"])] = r
        except (KeyError, TypeError, ValueError):
            continue
    return out


def _check_scaling(base: Dict[int, Dict[str, Any]],
                   new: Dict[int, Dict[str, Any]],
                   threshold: float, failures: List[str]) -> None:
    """Gate the sharded-pool device sweep: every new row must have run
    (no error, zero steady-state compiles); throughput is
    threshold-compared only where the baseline has the same device count
    (old baselines without scaling rows gate nothing)."""
    for d in sorted(new):
        nm = new[d]
        if nm.get("error"):
            failures.append(f"scaling d{d}: bench errored: "
                            f"{str(nm['error'])[:200]}")
            continue
        compiles = _num(nm, "steady_state_compiles")
        if compiles is None or compiles != 0:
            failures.append(f"scaling d{d}: {compiles} steady-state "
                            f"compiles (sharded pool must not recompile)")
        new_tps = _num(nm, "decode_sat_tok_per_s")
        bm = base.get(d)
        old_tps = _num(bm, "decode_sat_tok_per_s") if bm else None
        if old_tps is None or new_tps is None:
            if new_tps is not None:
                print(f"[bench-check] scaling d{d:d} "
                      f"{new_tps:8.1f} tok/s (no baseline row)")
            continue
        floor = old_tps * (1.0 - threshold)
        status = "ok" if new_tps >= floor else "REGRESSION"
        print(f"[bench-check] scaling d{d:d} {old_tps:8.1f} -> "
              f"{new_tps:8.1f} tok/s (floor {floor:.1f}) {status}")
        if new_tps < floor:
            failures.append(
                f"scaling d{d}: sat decode tok/s dropped {old_tps:.1f} -> "
                f"{new_tps:.1f} (> {threshold:.0%})")


def _scaling_table(base: Dict[int, Dict[str, Any]],
                   new: Dict[int, Dict[str, Any]]) -> List[str]:
    if not new:
        return []
    lines = ["", "### Sharded slot pool: tok/s vs devices", "",
             "| devices | sat decode tok/s (old → new) | compiles in run |",
             "|---|---|---|"]
    for d in sorted(set(base) | set(new)):
        bm, nm = base.get(d, {}), new.get(d, {})
        if nm.get("error"):
            lines.append(f"| {d} | ERROR | - |")
            continue
        lines.append(
            f"| {d} "
            f"| {_fmt(_num(bm, 'decode_sat_tok_per_s'))} → "
            f"{_fmt(_num(nm, 'decode_sat_tok_per_s'))} "
            f"| {_fmt(_num(nm, 'steady_state_compiles'), '.0f')} |")
    return lines


def _observability(doc) -> Dict[str, Any]:
    """The telemetry-overhead row. Empty for files that predate the
    observability layer — callers must not fail on those."""
    obs = doc.get("serve_stream", {}).get("observability", {})
    return obs if isinstance(obs, dict) else {}


def _check_observability(obs: Dict[str, Any], max_overhead: float,
                         failures: List[str]) -> None:
    """Gate the telemetry layer: with tracing + metrics fully enabled,
    saturated decode must stay within `max_overhead` of the telemetry-off
    engine in the SAME run (machine-independent ratio), with zero
    steady-state compiles. Baselines without the row gate nothing."""
    if not obs:
        print("[bench-check] observability: no row in the new run "
              "(pre-observability bench file) — skipping")
        return
    off = _num(obs, "decode_sat_tok_per_s_off")
    on = _num(obs, "decode_sat_tok_per_s_on")
    if off is None or on is None or off <= 0:
        failures.append("observability: on/off saturated decode tok/s "
                        "missing from the row")
        return
    overhead = (off - on) / off
    status = "ok" if overhead <= max_overhead else "TOO SLOW"
    print(f"[bench-check] observability telemetry-on {on:.1f} vs off "
          f"{off:.1f} tok/s ({overhead:+.2%} overhead, "
          f"max {max_overhead:.0%}) {status}")
    if overhead > max_overhead:
        failures.append(
            f"observability: telemetry costs {overhead:.2%} of saturated "
            f"decode ({off:.1f} -> {on:.1f} tok/s), over the "
            f"{max_overhead:.0%} budget")
    compiles = _num(obs, "steady_state_compiles")
    if compiles is None or compiles != 0:
        failures.append(f"observability: {compiles} steady-state compiles "
                        f"with telemetry on (must be zero)")


def _observability_table(obs: Dict[str, Any]) -> List[str]:
    if not obs:
        return []
    off = _num(obs, "decode_sat_tok_per_s_off")
    on = _num(obs, "decode_sat_tok_per_s_on")
    ovh = ((off - on) / off if off and on is not None else None)
    return ["", "### Observability overhead", "",
            "| sat decode tok/s (off → on) | overhead | compiles "
            "| trace events | metric series |",
            "|---|---|---|---|---|",
            f"| {_fmt(off)} → {_fmt(on)} "
            f"| {_fmt(None if ovh is None else 100 * ovh, '+.2f')}% "
            f"| {_fmt(_num(obs, 'steady_state_compiles'), '.0f')} "
            f"| {_fmt(_num(obs, 'trace_events'), '.0f')} "
            f"| {_fmt(_num(obs, 'metric_series'), '.0f')} |"]


def _drift_rows(doc) -> Dict[str, Dict[str, Any]]:
    """error_vs_length + sentinel rows; empty for files predating them."""
    ss = doc.get("serve_stream", {})
    out = {}
    for k in ("error_vs_length", "sentinel"):
        v = ss.get(k, {})
        if isinstance(v, dict) and v:
            out[k] = v
    return out


def _check_drift(rows: Dict[str, Dict[str, Any]], scale: float,
                 max_overhead: float, required: bool,
                 failures: List[str]) -> None:
    """Gate measured distillation drift against the static certificate and
    the sentinel's overhead. The certificate upper-bounds the filter-output
    error; `scale` leaves headroom for the (mild) nonlinear amplification
    through the rest of the block before it reaches the logits."""
    evl = rows.get("error_vs_length")
    if not evl:
        if required:
            failures.append("--drift: serve_stream.error_vs_length row "
                            "missing from the new run")
        else:
            print("[bench-check] drift: no error_vs_length row "
                  "(pre-sentinel bench file) — skipping")
        return
    bound = _num(evl, "certificate_total_l1")
    if bound is None or bound <= 0:
        failures.append("drift: certificate_total_l1 missing from the "
                        "error_vs_length row")
        return
    cap = scale * bound
    for p in evl.get("horizons", []):
        div = _num(p, "logit_div")
        ln = int(p.get("len", 0))
        if div is None:
            failures.append(f"drift: horizon {ln} has no logit_div")
            continue
        status = "ok" if div <= cap else "OVER CERTIFICATE"
        print(f"[bench-check] drift L={ln:<4d} logit_div {div:.3e} vs "
              f"{scale:.2f}x certificate ({cap:.3e}) {status}")
        if div > cap:
            failures.append(
                f"drift: horizon {ln} divergence {div:.3e} exceeds "
                f"{scale:.2f}x the static certificate bound {bound:.3e}")
    sent = rows.get("sentinel")
    if not sent:
        if required:
            failures.append("--drift: serve_stream.sentinel row missing "
                            "from the new run")
        return
    off = _num(sent, "decode_sat_tok_per_s_off")
    on = _num(sent, "decode_sat_tok_per_s_on")
    if off is None or on is None or off <= 0:
        failures.append("drift: sentinel on/off saturated decode tok/s "
                        "missing")
    else:
        overhead = (off - on) / off
        status = "ok" if overhead <= max_overhead else "TOO SLOW"
        print(f"[bench-check] drift sentinel-on {on:.1f} vs off {off:.1f} "
              f"tok/s ({overhead:+.2%} overhead, max {max_overhead:.0%}) "
              f"{status}")
        if overhead > max_overhead:
            failures.append(
                f"drift: sentinel costs {overhead:.2%} of saturated decode "
                f"({off:.1f} -> {on:.1f} tok/s), over the "
                f"{max_overhead:.0%} budget")
    compiles = _num(sent, "steady_state_compiles")
    if compiles is None or compiles != 0:
        failures.append(f"drift: {compiles} steady-state compiles with the "
                        f"sentinel armed (every shadow executable must be "
                        f"warmed in warmup())")


def _drift_table(rows: Dict[str, Dict[str, Any]]) -> List[str]:
    evl = rows.get("error_vs_length")
    if not evl:
        return []
    bound = _num(evl, "certificate_total_l1")
    lines = ["", "### Distillation drift vs exact epoch path", "",
             "| horizon | logit divergence | certificate l1 |",
             "|---|---|---|"]
    for p in evl.get("horizons", []):
        lines.append(f"| {int(p.get('len', 0))} "
                     f"| {_fmt(_num(p, 'logit_div'), '.3e')} "
                     f"| {_fmt(bound, '.3e')} |")
    sent = rows.get("sentinel")
    if sent:
        off = _num(sent, "decode_sat_tok_per_s_off")
        on = _num(sent, "decode_sat_tok_per_s_on")
        ovh = ((off - on) / off if off and on is not None else None)
        lines += ["",
                  f"sentinel: every {_fmt(_num(sent, 'drift_check_every'), '.0f')} "
                  f"ticks, overhead "
                  f"{_fmt(None if ovh is None else 100 * ovh, '+.2f')}%, "
                  f"{_fmt(_num(sent, 'steady_state_compiles'), '.0f')} "
                  f"steady-state compiles, max shadow divergence "
                  f"{_fmt(_num(sent, 'drift_max'), '.3e')}"]
    return lines


def _num(m: Dict[str, Any], key: str) -> Optional[float]:
    """Metric as float; tolerates old files with int/float drift or the key
    missing entirely."""
    v = m.get(key)
    if v is None:
        return None
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


def _gated_decode(m: Dict[str, Any]) -> Optional[float]:
    """Decode tok/s used for the spec-vs-plain gate: prefer the saturated
    measurement, fall back to the stream-derived one for old files."""
    v = _num(m, "decode_sat_tok_per_s")
    return v if v is not None else _num(m, "decode_tok_per_s")


def _fmt(v: Optional[float], spec: str = ".1f") -> str:
    return format(v, spec) if v is not None else "-"


def _summary_table(base: Dict[str, Dict[str, Any]],
                   new: Dict[str, Dict[str, Any]]) -> List[str]:
    lines = ["### Serving benchmark (`make bench-check`)", "",
             "| mode | tok/s (old → new) | decode tok/s (old → new) "
             "| sat decode tok/s | acceptance | tok/round |",
             "|---|---|---|---|---|---|"]
    for mode in sorted(set(base) | set(new)):
        bm, nm = base.get(mode, {}), new.get(mode, {})
        lines.append(
            f"| {mode} "
            f"| {_fmt(_num(bm, 'tok_per_s'))} → {_fmt(_num(nm, 'tok_per_s'))} "
            f"| {_fmt(_num(bm, 'decode_tok_per_s'))} → "
            f"{_fmt(_num(nm, 'decode_tok_per_s'))} "
            f"| {_fmt(_num(nm, 'decode_sat_tok_per_s'))} "
            f"| {_fmt(_num(nm, 'acceptance_rate'), '.2f')} "
            f"| {_fmt(_num(nm, 'tokens_per_slot_round'), '.2f')} |")
    spec = new.get("distilled_spec", {})
    if spec.get("autotune"):
        lines += ["", "<details><summary>distilled_spec autotune sweep"
                  "</summary>", "",
                  "| config | decode tok/s | acceptance | tok/round |",
                  "|---|---|---|---|"]
        for r in spec["autotune"]:
            lines.append(f"| {r.get('config', '?')} "
                         f"| {_fmt(_num(r, 'decode_tok_per_s'))} "
                         f"| {_fmt(_num(r, 'acceptance'), '.2f')} "
                         f"| {_fmt(_num(r, 'tokens_per_slot_round'), '.2f')} |")
        chosen = ("k{spec_k}/d{draft_order}/b{spec_branch}".format(**spec)
                  if "spec_k" in spec else "off")
        lines += ["", f"chosen: **{chosen}**", "", "</details>"]
    return lines


def _chaos_table(chaos: Dict[str, Dict[str, Any]]) -> List[str]:
    """Report-only chaos columns: recovered-fault counts per mode. The only
    gated number is `unrecovered` (requests that never completed)."""
    lines = ["", "### Chaos run (`make bench-chaos`)", "",
             "| mode | completed | ok / error | unrecovered | quarantines "
             "| reprefills | dispatch faults | deadline | watchdog "
             "| poisoned |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for mode in sorted(chaos):
        m = chaos[mode]
        r = m.get("resilience", {})
        lines.append(
            f"| {mode} "
            f"| {int(m.get('n_completed', 0))}"
            f"/{int(m.get('n_requests_expected', 0))} "
            f"| {int(m.get('n_ok', 0))} / {int(m.get('n_errors', 0))} "
            f"| {int(m.get('unrecovered', 0))} "
            f"| {int(r.get('health_failures', 0))} "
            f"| {int(r.get('slot_reprefills', 0))} "
            f"| {int(r.get('dispatch_faults', 0))} "
            f"| {int(r.get('deadline_expiries', 0))} "
            f"| {int(r.get('watchdog_trips', 0))} "
            f"| {int(r.get('poisoned', 0))} |")
    return lines


def _check_chaos(chaos: Dict[str, Dict[str, Any]],
                 failures: List[str]) -> None:
    for mode in sorted(chaos):
        m = chaos[mode]
        expected = int(m.get("n_requests_expected", 0))
        completed = int(m.get("n_completed", 0))
        unrec = max(int(m.get("unrecovered", 0)), expected - completed)
        status = "ok" if unrec == 0 else "UNRECOVERED"
        print(f"[bench-check] chaos {mode:15s} completed "
              f"{completed}/{expected} errors={int(m.get('n_errors', 0))} "
              f"faults_absorbed={int(m.get('total_faults', 0))} {status}")
        if unrec:
            failures.append(
                f"chaos {mode}: {unrec} request(s) never reached a terminal "
                f"status under the fault schedule")
        if mode == "distilled_drift":
            alarms = int(m.get("drift_alarms", 0))
            final = m.get("final_mode")
            print(f"[bench-check] chaos {mode:15s} drift_alarms={alarms} "
                  f"final_mode={final}")
            if alarms < 1:
                failures.append(
                    "chaos distilled_drift: the sentinel never alarmed on "
                    "the sign-flipped slot state")
            if final != "epoch":
                failures.append(
                    f"chaos distilled_drift: engine ended in mode "
                    f"{final!r}, expected demotion to 'epoch'")


def _write_summary(lines: List[str], path: Optional[str]) -> None:
    path = path or os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_serve.json to compare against "
                         "(optional when only --chaos is being checked)")
    ap.add_argument("--new", default="BENCH_serve.json",
                    help="freshly produced benchmark file")
    ap.add_argument("--chaos", default=None,
                    help="BENCH_chaos.json from `make bench-chaos`: fail if "
                         "any mode left requests that never completed under "
                         "the fault schedule (recovered-fault counters are "
                         "report-only)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated fractional tok/s drop per mode")
    ap.add_argument("--spec-ratio", type=float, default=1.0,
                    help="require new-run distilled_spec decode tok/s >= "
                         "this ratio times new-run plain distilled decode "
                         "tok/s, on the saturated metric when both report "
                         "it (0 disables)")
    ap.add_argument("--obs-overhead", type=float, default=0.02,
                    help="max tolerated saturated-decode slowdown with "
                         "telemetry (tracing + metrics) enabled, same-run "
                         "on-vs-off ratio (0 disables; files without the "
                         "observability row are skipped, not failed)")
    ap.add_argument("--drift", action="store_true",
                    help="require the drift rows (error_vs_length + "
                         "sentinel): fail when missing instead of skipping")
    ap.add_argument("--drift-scale", type=float, default=1.0,
                    help="max tolerated measured logit divergence as a "
                         "multiple of the static truncation certificate "
                         "(0 disables the drift gate)")
    ap.add_argument("--summary", type=str, default=None,
                    help="append the markdown comparison table to this file "
                         "(default: $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args()
    if not args.baseline and not args.chaos:
        ap.error("nothing to check: pass --baseline and/or --chaos")

    base: Dict[str, Dict[str, Any]] = {}
    new: Dict[str, Dict[str, Any]] = {}
    base_scaling: Dict[int, Dict[str, Any]] = {}
    new_scaling: Dict[int, Dict[str, Any]] = {}
    if args.baseline:
        with open(args.baseline) as f:
            base_doc = json.load(f)
        with open(args.new) as f:
            new_doc = json.load(f)
        base, new = _modes(base_doc), _modes(new_doc)
        base_scaling, new_scaling = _scaling(base_doc), _scaling(new_doc)

    failures: List[str] = []
    for mode, bm in sorted(base.items()):
        nm = new.get(mode)
        if nm is None:
            failures.append(f"mode {mode!r} disappeared from the new run")
            continue
        old_tps, new_tps = _num(bm, "tok_per_s"), _num(nm, "tok_per_s")
        if old_tps is None or new_tps is None:
            continue
        floor = old_tps * (1.0 - args.threshold)
        status = "ok" if new_tps >= floor else "REGRESSION"
        print(f"[bench-check] {mode:15s} {old_tps:8.1f} -> {new_tps:8.1f} "
              f"tok/s (floor {floor:.1f}) {status}")
        if new_tps < floor:
            failures.append(
                f"{mode}: tok/s dropped {old_tps:.1f} -> {new_tps:.1f} "
                f"(> {args.threshold:.0%})")

    # same-run speculation gate: spec must not trail plain decoding
    if args.spec_ratio > 0 and "distilled" in new:
        spec = new.get("distilled_spec")
        if spec is None:
            failures.append("distilled_spec mode missing from the new run")
        else:
            plain_d = _gated_decode(new["distilled"])
            spec_d = _gated_decode(spec)
            metric = ("decode_sat_tok_per_s"
                      if _num(new["distilled"], "decode_sat_tok_per_s")
                      is not None
                      and _num(spec, "decode_sat_tok_per_s") is not None
                      else "decode_tok_per_s")
            if plain_d is None or spec_d is None:
                failures.append("spec gate: decode tok/s missing")
            else:
                need = args.spec_ratio * plain_d
                status = "ok" if spec_d >= need else "BELOW PLAIN"
                print(f"[bench-check] distilled_spec {metric} {spec_d:.1f} "
                      f"vs {args.spec_ratio:.2f}x same-run distilled "
                      f"({plain_d:.1f}) = {need:.1f} {status}")
                if spec_d < need:
                    failures.append(
                        f"distilled_spec {metric} {spec_d:.1f} < "
                        f"{args.spec_ratio:.2f}x same-run distilled "
                        f"{plain_d:.1f}")

    if args.baseline:
        _check_scaling(base_scaling, new_scaling, args.threshold, failures)

    new_obs = _observability(new_doc) if args.baseline else {}
    if args.baseline and args.obs_overhead > 0:
        _check_observability(new_obs, args.obs_overhead, failures)

    drift_rows = _drift_rows(new_doc) if args.baseline else {}
    if args.baseline and args.drift_scale > 0:
        _check_drift(drift_rows, args.drift_scale, args.obs_overhead,
                     args.drift, failures)

    lines = _summary_table(base, new) if args.baseline else []
    lines += _observability_table(new_obs)
    lines += _drift_table(drift_rows)
    lines += _scaling_table(base_scaling, new_scaling)
    if args.chaos:
        with open(args.chaos) as f:
            chaos = json.load(f).get("serve_chaos", {}).get("modes", {})
        if not chaos:
            failures.append(f"{args.chaos} has no serve_chaos modes "
                            f"(chaos bench crashed?)")
        else:
            _check_chaos(chaos, failures)
            lines += _chaos_table(chaos)
    if failures:
        lines += ["", "**FAILED:**"] + [f"- {m}" for m in failures]
    else:
        lines += ["", "all serving throughput checks passed"]
    _write_summary(lines, args.summary)

    if failures:
        for msg in failures:
            print(f"[bench-check] FAIL: {msg}", file=sys.stderr)
        return 1
    print("[bench-check] all serving throughput checks passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
