"""Gemma-7B [arXiv:2403.08295].

Dense decoder, 28L d_model=3072 16H (kv=16, i.e. MHA at 7b) d_ff=24576
vocab=256000, GeGLU, head_dim=256, tied embeddings.
"""
from repro.configs.base import ATTN, ModelConfig, register


@register
def gemma_7b() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab=256000,
        act="geglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        tie_embeddings=True,
        pattern=(ATTN,),
        max_seq=8192,
    )
