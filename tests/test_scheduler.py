"""Continuous-batching scheduler: interleaved slot-pool serving must be
token-for-token identical to sequential single-request generation (greedy),
and the slot bookkeeping (admission, eviction, per-slot sampling params)
must be exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ATTN, HYENA, HyenaConfig, ModelConfig
from repro.distributed.sharding import unzip
from repro.models.model import (init_cache, init_params, prefill,
                                reset_cache_slot, write_cache_slot)
from repro.serve.engine import GenerationEngine
from repro.serve.sampling import sample_token_slots
from repro.serve.scheduler import (ContinuousBatchingEngine, SamplingParams,
                                   run_request_stream,
                                   synthesize_request_stream)

MAX_LEN = 48
PROMPT_LENS = (4, 7, 12, 20, 9)
GEN_LENS = (8, 5, 11, 6, 9)


def _hyena_cfg():
    return ModelConfig(name="sched-hyena", family="lcsm", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, vocab=64, act="gelu", norm="layernorm",
                       pattern=(HYENA,),
                       hyena=HyenaConfig(n_filter_heads=2, filter_order=16,
                                         filter_emb=9, distill_order=8),
                       max_seq=512, dtype="float32")


def _attn_cfg():
    return ModelConfig(name="sched-attn", family="dense", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, vocab=64, act="gelu", norm="layernorm",
                       pattern=(ATTN,), max_seq=512, dtype="float32")


@pytest.fixture(scope="module")
def hyena_model():
    cfg = _hyena_cfg()
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


@pytest.fixture(scope="module")
def attn_model():
    cfg = _attn_cfg()
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _prompts(vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32)
            for n in PROMPT_LENS]


def _sequential_greedy(cfg, params, prompts, gens, mode):
    eng = GenerationEngine(params, cfg, max_len=MAX_LEN, mode=mode)
    return [np.asarray(eng.generate(jax.random.PRNGKey(1),
                                    jnp.asarray(p)[None], g)[0][0])
            for p, g in zip(prompts, gens)]


# ---------------------------------------------------------------------------
# Consistency: interleaved == sequential, token for token
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("overlap", [True, False])
@pytest.mark.parametrize("mode", ["distilled", "cached_conv", "epoch"])
def test_interleaved_matches_sequential_lcsm(hyena_model, mode, overlap):
    """5 concurrent requests with different prompt lengths through 2 slots
    (forces queueing + eviction + slot reuse) produce exactly the tokens of
    5 sequential single-request runs — in all three LCSM deployment modes,
    with both the overlapped (async) and synchronous host loops."""
    cfg, params = hyena_model
    prompts = _prompts(cfg.vocab)
    want = _sequential_greedy(cfg, params, prompts, GEN_LENS, mode)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode=mode, overlap=overlap)
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(prompts, GEN_LENS)]
    eng.run()
    for r, w in zip(reqs, want):
        assert r.status == "finished" and r.finish_reason == "max_tokens"
        np.testing.assert_array_equal(np.asarray(r.tokens), w)


def test_interleaved_matches_sequential_attention(attn_model):
    """Same property for the attention-KV slot pool (per-slot positions in
    the kv cache writes, rope, and causal masks)."""
    cfg, params = attn_model
    prompts = _prompts(cfg.vocab)
    want = _sequential_greedy(cfg, params, prompts, GEN_LENS, "distilled")
    eng = ContinuousBatchingEngine(params, cfg, n_slots=3, max_len=MAX_LEN)
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(prompts, GEN_LENS)]
    eng.run()
    for r, w in zip(reqs, want):
        np.testing.assert_array_equal(np.asarray(r.tokens), w)


def test_reset_on_evict_is_equivalent(hyena_model):
    """Slot reuse must not leak state: explicit zeroing on eviction changes
    nothing (admission overwrites the slot)."""
    cfg, params = hyena_model
    prompts = _prompts(cfg.vocab)
    outs = []
    for reset in (False, True):
        eng = ContinuousBatchingEngine(params, cfg, n_slots=2,
                                       max_len=MAX_LEN,
                                       reset_on_evict=reset)
        reqs = [eng.submit(p, max_new_tokens=g)
                for p, g in zip(prompts, GEN_LENS)]
        eng.run()
        outs.append([list(r.tokens) for r in reqs])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Slot bookkeeping
# ---------------------------------------------------------------------------
def test_admission_eviction_bookkeeping(hyena_model):
    # overlap=False: this test asserts host-visible state between individual
    # ticks, which the synchronous loop defines (the overlapped loop retires
    # each tick's tokens one step later by design)
    cfg, params = hyena_model
    prompts = _prompts(cfg.vocab)[:3]
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   max_prefills_per_step=2, overlap=False)
    reqs = [eng.submit(p, max_new_tokens=4) for p in prompts]
    assert [r.status for r in reqs] == ["queued"] * 3
    eng.step()
    # two slots filled, third request still queued; FIFO admission order
    assert reqs[0].status == "running" and reqs[1].status == "running"
    assert reqs[2].status == "queued"
    assert eng.n_active == 2 and eng.n_free == 0 and len(eng.queue) == 1
    assert {reqs[0].slot, reqs[1].slot} == {0, 1}
    # first token was emitted at admission, then one decode token
    assert len(reqs[0].tokens) == 2
    eng.run()
    assert all(r.status == "finished" for r in reqs)
    assert all(len(r.tokens) == 4 for r in reqs)
    assert all(r.finish_reason == "max_tokens" for r in reqs)
    assert eng.n_active == 0 and eng.n_free == 2 and not eng.queue
    assert eng.stats["admitted"] == 3 and eng.stats["evicted"] == 3
    # request 3 reused a slot freed by an earlier eviction
    assert reqs[2].t_admitted >= min(reqs[0].t_finished, reqs[1].t_finished)


def test_eos_evicts_early(hyena_model):
    cfg, params = hyena_model
    prompts = _prompts(cfg.vocab)
    base = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=MAX_LEN)
    ref = base.submit(prompts[0], max_new_tokens=8)
    base.run()
    eos = ref.tokens[2]
    eng = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=MAX_LEN)
    req = eng.submit(prompts[0], max_new_tokens=8, eos_id=eos)
    eng.run()
    assert req.finish_reason == "eos"
    assert req.tokens == ref.tokens[:3]        # stops at (and includes) EOS


def test_submit_validation(hyena_model):
    cfg, params = hyena_model
    eng = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(12, np.int32), max_new_tokens=8)   # 20 > max_len
    with pytest.raises(ValueError):
        eng.submit(np.zeros(4, np.int32), max_new_tokens=0)


def test_request_stream_driver(hyena_model):
    cfg, params = hyena_model
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN)
    stream = synthesize_request_stream(
        np.random.default_rng(3), 5, rate=200.0, prompt_lens=(4, 8),
        gen_tokens=(2, 5), vocab=cfg.vocab)
    m = run_request_stream(eng, stream)
    assert m["n_requests"] == 5
    assert m["n_tokens"] == sum(len(r.tokens) for r in eng.finished)
    assert m["p99_latency_s"] >= m["p50_latency_s"] >= 0.0
    assert all(r.ttft <= r.latency for r in eng.finished)


# ---------------------------------------------------------------------------
# Per-slot sampling params
# ---------------------------------------------------------------------------
def test_sample_token_slots_per_row_params():
    """Each row honors its own temperature/top-k/top-p."""
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([
        [0.0, 1.0, 2.0, 3.0, 10.0, 4.0, 5.0, 6.0],
        [0.0, 1.0, 2.0, 3.0, 10.0, 4.0, 5.0, 6.0],
        [0.0, 1.0, 2.0, 3.0, 10.0, 4.0, 5.0, 6.0],
        [0.0, 1.0, 2.0, 3.0, 10.0, 4.0, 5.0, 6.0],
    ], jnp.float32)
    temperature = jnp.asarray([0.0, 1.0, 1.0, 1.0])
    top_k = jnp.asarray([0, 1, 3, 0])
    top_p = jnp.asarray([1.0, 1.0, 1.0, 0.01])
    hits = set()
    for s in range(64):
        toks = np.asarray(sample_token_slots(
            jax.random.fold_in(key, s), logits, temperature=temperature,
            top_k=top_k, top_p=top_p))
        assert toks[0] == 4                    # greedy row
        assert toks[1] == 4                    # top-k = 1 -> argmax
        assert toks[2] in (4, 6, 7)            # top-3 support only
        assert toks[3] == 4                    # tiny nucleus -> argmax
        hits.add(int(toks[2]))
    assert len(hits) > 1                       # actually samples, not greedy


def test_engine_honors_per_slot_sampling(hyena_model):
    """top_k=1 sampling at high temperature equals greedy — co-resident with
    a genuinely stochastic request (different per-slot params in one pool)."""
    cfg, params = hyena_model
    prompts = _prompts(cfg.vocab)
    want = _sequential_greedy(cfg, params, prompts[:1], [8], "distilled")[0]
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN)
    r_det = eng.submit(prompts[0], max_new_tokens=8,
                       sampling=SamplingParams(temperature=2.0, top_k=1))
    eng.submit(prompts[1], max_new_tokens=8,
               sampling=SamplingParams(temperature=1.5, top_p=0.9))
    eng.run()
    np.testing.assert_array_equal(np.asarray(r_det.tokens), want)


# ---------------------------------------------------------------------------
# Slot-indexed cache helpers
# ---------------------------------------------------------------------------
def test_write_and_reset_cache_slot(hyena_model):
    cfg, params = hyena_model
    pool, _ = unzip(init_cache(cfg, 3, MAX_LEN, per_slot=True))
    toks = jnp.asarray(_prompts(cfg.vocab)[0])[None]
    single, _ = prefill(params, toks, cfg, max_len=MAX_LEN)
    pool = write_cache_slot(pool, single, 1)
    assert list(np.asarray(pool["pos"])) == [0, toks.shape[1], 0]
    slot_rows = jax.tree.map(lambda p: p[:, 1], pool["groups"])
    src_rows = jax.tree.map(lambda s: s[:, 0], single["groups"])
    for a, b in zip(jax.tree.leaves(slot_rows), jax.tree.leaves(src_rows)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    # untouched slots stay zero
    for leaf in jax.tree.leaves(jax.tree.map(lambda p: p[:, 0],
                                             pool["groups"])):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0
    pool = reset_cache_slot(pool, 1)
    assert int(pool["pos"][1]) == 0
    for leaf in jax.tree.leaves(jax.tree.map(lambda p: p[:, 1],
                                             pool["groups"])):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0
