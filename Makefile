# Tiered test entry points (see pytest.ini: `slow` tests are deselected by
# default, so `test-fast` is the tier-1 suite the driver runs).
PY := PYTHONPATH=src python

.PHONY: test-fast test-all test-slow bench bench-serve bench-check bench-chaos

test-fast:
	$(PY) -m pytest -x -q

test-all:
	$(PY) -m pytest -q -m "slow or not slow"

test-slow:
	$(PY) -m pytest -q -m slow

bench:
	$(PY) -m benchmarks.run

# serving perf trajectory: tok/s (+ decode tok/s and speculative acceptance),
# latency/TTFT percentiles, and prefill compile counts per mode, written to
# BENCH_serve.json for cross-PR tracking. Also measures the telemetry layer
# (tracer + metrics) on vs off in the same run — the `observability` row —
# the distilled-vs-exact drift at growing horizons (`error_vs_length`), the
# drift sentinel's saturated-decode overhead (`sentinel`; gated <=2% with
# zero steady-state compiles by check_regression --drift), and writes the
# telemetry-on request trace to BENCH_serve_trace.json (Chrome-trace JSON;
# load in https://ui.perfetto.dev).
bench-serve:
	$(PY) -m benchmarks.run --only serve_stream --json BENCH_serve.json

# regression gate: re-run the serving bench and compare against the
# committed baseline (fails on a >15% tok/s drop, a speculative-decode
# floor violation, or >2% telemetry overhead on saturated decode).
# CI uses this with the pre-bench copy as baseline.
bench-check:
	cp BENCH_serve.json /tmp/BENCH_baseline.json
	$(MAKE) bench-serve
	$(PY) -m benchmarks.check_regression \
	    --baseline /tmp/BENCH_baseline.json --new BENCH_serve.json

# chaos gate: the request stream under the standard seeded fault schedule
# (benchmarks/bench_throughput.CHAOS_SCHEDULE) per cache kind, plus the
# distilled_drift row (silent sign-flip of a slot's modal state; the drift
# sentinel must alarm and demote the engine to the exact epoch path). Fails
# if any request never reached a terminal status; recovered-fault counters
# (quarantines, re-prefills, watchdog trips, ...) are report-only. Runs
# nightly in CI.
bench-chaos:
	$(PY) -m benchmarks.run --only serve_chaos --json BENCH_chaos.json
	$(PY) -m benchmarks.check_regression --chaos BENCH_chaos.json
