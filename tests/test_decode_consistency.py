"""Recurrent decode must match the full (convolution/parallel) forward —
Sec. 2.2's mode-switching requirement. Hyena archs are checked after
distillation in test_system.py (pre-distillation mismatch is expected)."""
import jax
import jax.numpy as jnp
import pytest

# every arch in the pool x python-loop decode: ~90s — tier-2. The fast suite
# covers the same mode-switch invariant via test_scheduler (pooled vs
# sequential decode) and test_archs_smoke::test_prefill_decode_runs.
pytestmark = pytest.mark.slow

from repro.configs import get_config, smoke_config
from repro.distributed.sharding import unzip
from repro.models.model import decode_step, forward, init_params, prefill

NATIVE_RECURRENT = ["mamba2-130m", "recurrentgemma-9b"]
ATTENTION = ["llama3.2-3b", "gemma-7b", "starcoder2-3b", "mistral-nemo-12b",
             "qwen2-vl-72b", "whisper-medium", "granite-moe-3b-a800m",
             "dbrx-132b"]


def _run(arch, tol):
    cfg = smoke_config(get_config(arch)).replace(dtype="float32")
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    key = jax.random.PRNGKey(1)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    fe = None
    off = 0
    if cfg.frontend != "none":
        fe = jnp.ones((B, cfg.frontend_len, cfg.d_model), jnp.float32) * 0.01
        if not cfg.enc_dec:
            off = cfg.frontend_len
    full, _ = forward(params, toks, cfg, frontend=fe)
    P = S - 6
    cache, last = prefill(params, toks[:, :P], cfg, max_len=64, frontend=fe)
    errs = [float(jnp.max(jnp.abs(last - full[:, P - 1 + off])))]
    for t in range(P, S):
        cache, lg = decode_step(params, cache, toks[:, t:t + 1], cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t + off]))))
    assert max(errs) < tol, (arch, max(errs))


@pytest.mark.parametrize("arch", NATIVE_RECURRENT)
def test_native_recurrence_matches_parallel(arch):
    _run(arch, tol=5e-3)


@pytest.mark.parametrize("arch", ATTENTION)
def test_kv_cache_matches_full_attention(arch):
    _run(arch, tol=5e-2)      # kv cache is bf16 -> ~1e-2 logit tolerance


def test_ring_buffer_local_attention():
    """Windowed decode past the window size must match full forward
    (exercises the ring-buffer kv cache)."""
    cfg = smoke_config(get_config("recurrentgemma-9b")).replace(dtype="float32")
    # window=64 (smoke); decode beyond 64 tokens
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    key = jax.random.PRNGKey(2)
    B, S = 1, 96
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full, _ = forward(params, toks, cfg)
    P = 80   # > window
    cache, last = prefill(params, toks[:, :P], cfg, max_len=S)
    errs = [float(jnp.max(jnp.abs(last - full[:, P - 1])))]
    for t in range(P, S):
        cache, lg = decode_step(params, cache, toks[:, t:t + 1], cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 5e-2, errs
