"""StarCoder2-3B [arXiv:2402.19173].

Dense decoder, 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152,
RoPE, layernorm, gelu MLP (non-gated).
"""
from repro.configs.base import ATTN, ModelConfig, register


@register
def starcoder2_3b() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        head_dim=128,
        d_ff=12288,
        vocab=49152,
        act="gelu",
        norm="layernorm",
        rope_theta=100_000.0,
        pattern=(ATTN,),
        max_seq=16384,
    )
