"""Serving telemetry: streaming metrics registry + XLA compile tracking.

Two halves:

1. **MetricsRegistry** — counters, gauges, and fixed-bucket histograms fed
   live by the scheduler (`serve/scheduler.py`) and the speculative
   controller (`serve/speculative.py`): tick latency, TTFT, end-to-end
   latency, queue depth, per-shard slot occupancy, spec acceptance and
   window sizes, batch fill ratio. One registry is the single source of
   truth for the engine, `run_request_stream`'s percentiles, and
   BENCH_serve.json. Exposition: Prometheus text (`to_prometheus()`), a
   JSON snapshot (`snapshot()`), and an optional background HTTP endpoint
   (`start_metrics_server`, wired to ``launch.serve --metrics-port``).
   Everything is plain host-side Python — the observability overhead gate
   holds telemetry to <= 2% of saturated-decode tok/s with zero
   steady-state compiles. ``MetricsRegistry(enabled=False)`` hands out
   shared null instruments, so instrumented hot paths cost one no-op call
   when metrics are off.

2. **Compile accounting** — the bucketed-prefill claim (O(#buckets) prefill
   executables instead of O(#distinct prompt lengths)) is asserted, not
   eyeballed: a process-wide listener on jax.monitoring's backend-compile
   event counts every XLA compilation, and per-callable executable counts
   come from the jit cache (`jit_cache_size`). jax.monitoring has no
   unregister, so the listener is installed once and counts monotonically;
   use `count_compiles()` scopes for deltas.
"""
from __future__ import annotations

import bisect
import contextlib
import json
import math
import threading
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


# ---------------------------------------------------------------------------
# metric instruments
# ---------------------------------------------------------------------------
# fixed default buckets — stable across runs so BENCH columns and Prometheus
# series never change shape
DEFAULT_TIME_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0)
WINDOW_BUCKETS: Tuple[float, ...] = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32)
RATIO_BUCKETS: Tuple[float, ...] = (0.0, 0.125, 0.25, 0.375, 0.5, 0.625,
                                    0.75, 0.875, 1.0)
DEPTH_BUCKETS: Tuple[float, ...] = (0, 1, 2, 4, 8, 16, 32, 64, 128)
# log-spaced |log-softmax| divergence buckets for the drift sentinel: the
# healthy distilled-vs-exact gap sits near float32 noise (1e-6..1e-3), a
# drifting slot climbs orders of magnitude above it
DRIFT_BUCKETS: Tuple[float, ...] = (
    1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "help", "_n")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._n = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        self._n += n

    @property
    def value(self):
        return self._n

    def snapshot(self):
        return self._n


class Gauge:
    """Last-value gauge."""

    __slots__ = ("name", "help", "_v")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._v = 0.0

    def set(self, v: Union[int, float]) -> None:
        self._v = v

    def inc(self, n: Union[int, float] = 1) -> None:
        self._v += n

    @property
    def value(self):
        return self._v

    def snapshot(self):
        return self._v


class Histogram:
    """Fixed-bucket histogram with percentile estimation.

    `buckets` are the finite upper bounds (ascending); an implicit +Inf
    overflow bucket catches the rest. `observe` is O(log #buckets).
    `percentile(q)` interpolates linearly inside the covering bucket and
    clamps to the observed min/max, so the estimate is always within the
    observed range and monotone in q.
    """

    __slots__ = ("name", "help", "bounds", "_counts", "_sum", "_count",
                 "_min", "_max")
    kind = "histogram"

    def __init__(self, name: str, buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                 help: str = ""):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or list(bounds) != sorted(set(bounds)):
            raise ValueError("histogram buckets must be ascending and unique")
        self.name = name
        self.help = help
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)   # +1: overflow (+Inf)
        self._sum = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self._counts[bisect.bisect_left(self.bounds, v)] += 1
        self._sum += v
        self._count += 1
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (0..100); NaN when empty."""
        if self._count == 0:
            return math.nan
        target = max(min(q, 100.0), 0.0) / 100.0 * self._count
        cum = 0
        for i, c in enumerate(self._counts):
            if c and cum + c >= target:
                lo = self._min if i == 0 else self.bounds[i - 1]
                hi = self._max if i == len(self.bounds) else self.bounds[i]
                lo = max(lo, self._min)
                hi = min(hi, self._max)
                frac = (target - cum) / c
                return min(max(lo + frac * (hi - lo), self._min), self._max)
            cum += c
        return self._max

    def snapshot(self) -> Dict[str, Any]:
        cum = 0
        buckets = {}
        for b, c in zip(self.bounds, self._counts):
            cum += c
            buckets[f"{b:g}"] = cum
        buckets["+Inf"] = self._count
        return {
            "count": self._count,
            "sum": self._sum,
            "min": self._min if self._count else None,
            "max": self._max if self._count else None,
            "p50": self.percentile(50) if self._count else None,
            "p99": self.percentile(99) if self._count else None,
            "buckets": buckets,
        }


class _NullInstrument:
    """Shared no-op counter/gauge/histogram handed out by a disabled
    registry: instrumented code keeps unconditional `.inc()/.observe()`
    calls on the hot path and pays one no-op method call when metrics are
    off."""

    __slots__ = ()
    name = "<disabled>"
    help = ""
    count = 0
    sum = 0.0
    value = 0

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def percentile(self, q):
        return math.nan

    def snapshot(self):
        return None


_NULL_INSTRUMENT = _NullInstrument()


class MetricsRegistry:
    """Named instrument registry with Prometheus/JSON exposition.

    `counter` / `gauge` / `histogram` get-or-create (a name maps to exactly
    one instrument kind — a kind clash raises). With ``enabled=False``
    every accessor returns the shared null instrument and exposition is
    empty, which is the telemetry-off configuration the observability
    bench row measures against.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._instruments: Dict[str, Any] = {}

    # -- get-or-create -------------------------------------------------
    def _get(self, name: str, kind, **kw):
        if not self.enabled:
            return _NULL_INSTRUMENT
        inst = self._instruments.get(name)
        if inst is None:
            inst = kind(name, **kw)
            self._instruments[name] = inst
        elif not isinstance(inst, kind):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{inst.kind}, not {kind.__name__.lower()}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                  help: str = "") -> Histogram:
        return self._get(name, Histogram, buckets=buckets, help=help)

    def get(self, name: str):
        """Registered instrument or None (never creates)."""
        return self._instruments.get(name)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    # -- exposition ----------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable {name: value} snapshot; histograms expand to
        their count/sum/percentiles/cumulative-bucket dict."""
        return {name: inst.snapshot()
                for name, inst in sorted(self._instruments.items())}

    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: List[str] = []
        for name, inst in sorted(self._instruments.items()):
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            lines.append(f"# TYPE {name} {inst.kind}")
            if inst.kind == "histogram":
                cum = 0
                for b, c in zip(inst.bounds, inst._counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{b:g}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {inst.count}')
                lines.append(f"{name}_sum {inst.sum:g}")
                lines.append(f"{name}_count {inst.count}")
            else:
                lines.append(f"{name} {inst.value:g}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# background stats endpoint (launch.serve --metrics-port)
# ---------------------------------------------------------------------------
def start_metrics_server(registry: MetricsRegistry, port: int = 0, *,
                         tracer=None, extra=None, host: str = "127.0.0.1"):
    """Serve the registry over HTTP in a daemon thread.

      GET /metrics       Prometheus text exposition
      GET /metrics.json  JSON snapshot (plus `extra()`'s dict, if given)
      GET /trace.json    Chrome-trace export of `tracer` (404 without one)

    Returns the HTTPServer; `server.server_address[1]` is the bound port
    (useful with port=0), `server.shutdown()` stops it.
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 (http.server API)
            if self.path in ("/metrics", "/"):
                body = registry.to_prometheus().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif self.path == "/metrics.json":
                doc = {"metrics": registry.snapshot()}
                if extra is not None:
                    doc.update(extra())
                body = json.dumps(doc, default=str).encode()
                ctype = "application/json"
            elif self.path == "/trace.json" and tracer is not None \
                    and getattr(tracer, "enabled", False):
                body = json.dumps(tracer.to_chrome_trace()).encode()
                ctype = "application/json"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):  # silence per-request stderr noise
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name="serve-metrics-http")
    thread.start()
    return server


# ---------------------------------------------------------------------------
# XLA compile accounting
# ---------------------------------------------------------------------------
class _CompileCounter:
    def __init__(self) -> None:
        self._n = 0
        self._installed = False

    def install(self) -> "_CompileCounter":
        if not self._installed:
            jax.monitoring.register_event_duration_secs_listener(self._on_event)
            self._installed = True
        return self

    def _on_event(self, name: str, duration: float, **kwargs) -> None:
        if name == _COMPILE_EVENT:
            self._n += 1

    @property
    def count(self) -> int:
        return self._n


compile_counter = _CompileCounter()


class CompileScope:
    """Result object of `count_compiles()`: `.compiles` is the number of XLA
    backend compilations that happened inside the scope."""

    def __init__(self) -> None:
        self.compiles: Optional[int] = None


@contextlib.contextmanager
def count_compiles():
    c = compile_counter.install()
    scope = CompileScope()
    start = c.count
    try:
        yield scope
    finally:
        scope.compiles = c.count - start


_JIT_CACHE_PROBES = ("_cache_size", "cache_size")
_jit_cache_warned = False


def jit_cache_size(fn, *, warn: bool = True) -> Optional[int]:
    """Number of compiled executables held by a jax.jit-wrapped callable
    (one per distinct input signature).

    The underlying API is private and has moved across jax versions, so
    this probes the known spellings (`_cache_size()` / `cache_size()`,
    method or attribute) and degrades to None *loudly* — a one-time
    RuntimeWarning — when none resolves, rather than silently lying about
    compile accounting."""
    global _jit_cache_warned
    for attr in _JIT_CACHE_PROBES:
        probe = getattr(fn, attr, None)
        if probe is None:
            continue
        try:
            n = probe() if callable(probe) else probe
            if n is not None:
                return int(n)
        except Exception:
            continue
    if warn and not _jit_cache_warned:
        _jit_cache_warned = True
        warnings.warn(
            "jit executable-count API unavailable on this jax version "
            f"(probed {_JIT_CACHE_PROBES} on {type(fn).__name__}); compile "
            "accounting degrades to None", RuntimeWarning, stacklevel=2)
    return None


RESILIENCE_KEYS = (
    "health_failures",      # device health bitvector flagged a slot
    "slot_reprefills",      # quarantined slot re-prefilled from its tokens
    "spec_demotions",       # slot demoted from speculation to plain decode
    "engine_demotions",     # engine walked one rung down the mode ladder
    "deadline_expiries",    # request evicted past its deadline
    "rejected",             # admission refused: queue at capacity
    "poisoned",             # request finished with error after max retries
    "dispatch_faults",      # dispatch raised and was recovered
    "watchdog_trips",       # host tick exceeded the watchdog latency
    "checkpoint_saves",
    "checkpoint_restores",
    "spec_window_syncs",    # controller window vector uploaded to the pool
    "drift_checks",         # sentinel shadow-decodes of a resident slot
    "drift_alarms",         # sentinel divergence exceeded drift_tol
)


class ResilienceCounters:
    """Resettable event counters for the engine's resilience layer. Extra
    (non-standard) keys are allowed so tests / future paths can piggyback;
    `snapshot()` always reports every standard key (zeros included) so
    BENCH_serve.json columns stay stable across runs.

    When bound to a MetricsRegistry (`registry=`), every bump also feeds a
    `serve_resilience_<key>` counter there, so the registry is the one
    source of truth for exposition while this object keeps its resettable
    BENCH-facing snapshot."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 prefix: str = "serve_resilience_") -> None:
        self._reg = registry
        self._prefix = prefix
        self.reset()

    def reset(self) -> None:
        self._c = {k: 0 for k in RESILIENCE_KEYS}

    def bump(self, key: str, n: int = 1) -> None:
        self._c[key] = self._c.get(key, 0) + int(n)
        if self._reg is not None:
            self._reg.counter(self._prefix + key).inc(int(n))

    def get(self, key: str) -> int:
        return int(self._c.get(key, 0))

    def snapshot(self) -> dict:
        return {k: int(v) for k, v in self._c.items()}

    @property
    def total_faults(self) -> int:
        """Faults the engine absorbed (recovered or degraded gracefully)."""
        return sum(self.get(k) for k in ("health_failures", "dispatch_faults",
                                         "deadline_expiries", "rejected",
                                         "watchdog_trips"))


def speculative_summary(stats, spec_k: Optional[int] = None) -> dict:
    """Acceptance-rate report from an engine's `stats` dict: drafted vs
    accepted counts, the acceptance rate, and the mean emitted tokens per
    speculating (round, slot) — accepted drafts + 1 correction token.
    Rates are None (JSON null) rather than NaN when nothing was drafted.

    Slot-rounds come from the engine's dispatch-time `spec_slot_rounds`
    counter when present — with per-slot adaptive windows the drafted count
    no longer implies the round count. For stats dicts from older runs the
    fallback chain is explicit (and reported in
    `tokens_per_slot_round_basis`):

      1. `spec_slot_rounds` present and nonzero — the real counter;
      2. else `spec_k` given — `drafted / spec_k` (fixed-window runs);
      3. else, with drafted tokens but no divisor, `tokens_per_slot_round`
         is None and a RuntimeWarning flags the gap — it must never look
         like "no speculation happened".
    """
    drafted = int(stats.get("spec_drafted", 0))
    accepted = int(stats.get("spec_accepted", 0))
    slot_rounds = stats.get("spec_slot_rounds")
    basis = "spec_slot_rounds"
    if not slot_rounds:
        if spec_k:
            slot_rounds = drafted / spec_k
            basis = "spec_k"
        else:
            slot_rounds = 0
            basis = None
            if drafted:
                warnings.warn(
                    f"speculative_summary: {drafted} drafted tokens but no "
                    "spec_slot_rounds counter and no spec_k fallback — "
                    "tokens_per_slot_round is unknown (None)",
                    RuntimeWarning, stacklevel=2)
    return {
        "spec_rounds": int(stats.get("spec_rounds", 0)),
        "spec_drafted": drafted,
        "spec_accepted": accepted,
        "acceptance_rate": accepted / drafted if drafted else None,
        "tokens_per_slot_round": (accepted / slot_rounds + 1.0
                                  if slot_rounds else None),
        "tokens_per_slot_round_basis": basis if slot_rounds else None,
    }
