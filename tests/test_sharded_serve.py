"""Sharded slot pool: correctness on a multi-device data mesh.

The heavyweight checks spawn a fresh interpreter with 4 forced host devices
(the main test process keeps a single device) and assert the contract from
serve/README.md "Sharded slot pool": greedy serving on a 4-way sharded pool
is token-for-token identical to the single-device engine — distilled,
cached-conv, and epoch modes, speculation on and off — with ZERO
steady-state XLA
compiles, and checkpoints restore only into the same mesh layout.

The fast single-device tests cover the pieces the sharding work flushed
out: the masked admission scatter (`write_cache_slots` must drop dummy rows
by explicit mask, not by out-of-bounds scatter semantics), the sharded
spec-window upload counter, and the format-2 checkpoint mesh metadata.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HYENA, HyenaConfig, ModelConfig
from repro.distributed.sharding import unzip
from repro.models.model import (gather_cache_rows, init_cache, init_params,
                                write_cache_slots)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str, n_devices: int = 4):
    code = textwrap.dedent(body)
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_devices}",
               PYTHONPATH=SRC)
    env.pop("REPRO_SLOT_MESH", None)      # explicit meshes only, per test
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stdout + p.stderr


_COMMON = """
import jax, numpy as np
from repro.configs.base import ModelConfig, HyenaConfig, HYENA
from repro.models.model import init_params
from repro.distributed.sharding import unzip
from repro.launch.mesh import make_slot_mesh
from repro.serve.scheduler import (ContinuousBatchingEngine, Request,
                                   SamplingParams)
from repro.serve.metrics import count_compiles

cfg = ModelConfig(name="shard-hyena", family="lcsm", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
                  act="gelu", norm="layernorm", pattern=(HYENA,),
                  hyena=HyenaConfig(n_filter_heads=2, filter_order=16,
                                    filter_emb=9, distill_order=8),
                  max_seq=512, dtype="float32")
params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
LENS = ((4, 8), (7, 5), (12, 11), (20, 6), (9, 9))

def make_reqs():
    rng = np.random.default_rng(0)
    return [Request(rid=rid, prompt=rng.integers(0, cfg.vocab, size=pl)
                    .astype(np.int32), max_new_tokens=gl,
                    sampling=SamplingParams())
            for rid, (pl, gl) in enumerate(LENS)]

def run(mesh, mode, spec_k, count=False):
    eng = ContinuousBatchingEngine(params, cfg, n_slots=4, max_len=48,
                                   mode=mode, spec_k=spec_k, mesh=mesh)
    eng.warmup(tuple(pl for pl, _ in LENS))
    reqs = make_reqs()
    for r in reqs[:4]:
        eng.submit_request(r)
    eng.step(); eng.step()
    n = None
    if count:
        with count_compiles() as scope:
            eng.submit_request(reqs[4])
            while eng.has_work:
                eng.step()
        n = scope.compiles
    else:
        eng.submit_request(reqs[4])
        while eng.has_work:
            eng.step()
    return {r.rid: list(r.tokens) for r in eng.finished}, n, eng
"""


def test_sharded_greedy_token_identity_distilled():
    """4-way sharded pool == single device, distilled mode, spec off and on
    (shared-state draft), with zero steady-state compiles sharded."""
    run_sub(_COMMON + """
for spec in (0, 2):
    base, _, _ = run(None, "distilled", spec)
    shard, n, _ = run(make_slot_mesh(4), "distilled", spec, count=True)
    assert base == shard, (spec, base, shard)
    assert n == 0, f"spec={spec}: {n} steady-state compiles on the mesh"
""")


def test_sharded_greedy_token_identity_cached_conv():
    """4-way sharded pool == single device, cached-conv mode, spec off and
    on (separate native draft pool), zero steady-state compiles sharded."""
    run_sub(_COMMON + """
for spec in (0, 2):
    base, _, _ = run(None, "cached_conv", spec)
    shard, n, _ = run(make_slot_mesh(4), "cached_conv", spec, count=True)
    assert base == shard, (spec, base, shard)
    assert n == 0, f"spec={spec}: {n} steady-state compiles on the mesh"
""")


def test_sharded_greedy_token_identity_epoch():
    """4-way sharded pool == single device, epoch mode (exact FutureFill
    fallback), spec off and on, zero steady-state compiles sharded."""
    run_sub(_COMMON + """
for spec in (0, 2):
    base, _, _ = run(None, "epoch", spec)
    shard, n, _ = run(make_slot_mesh(4), "epoch", spec, count=True)
    assert base == shard, (spec, base, shard)
    assert n == 0, f"spec={spec}: {n} steady-state compiles on the mesh"
""")


def test_sharded_checkpoint_restore_same_mesh():
    """Mid-run snapshot of a sharded engine restores into a fresh engine on
    the same mesh and continues token-identically; restoring it into a
    single-device engine (or a format-1 snapshot into a sharded engine)
    raises a clear layout error; a non-divisible n_slots is rejected."""
    run_sub(_COMMON + """
from repro.serve.checkpoint import restore_engine, save_engine

mesh = make_slot_mesh(4)
base, _, _ = run(None, "distilled", 0)

eng = ContinuousBatchingEngine(params, cfg, n_slots=4, max_len=48,
                               mode="distilled", mesh=mesh)
eng.warmup(tuple(pl for pl, _ in LENS))
for r in make_reqs():
    eng.submit_request(r)
for _ in range(3):
    eng.step()
import pickle
state = pickle.loads(pickle.dumps(save_engine(eng)))
assert state["format"] == 2
assert state["mesh"] is not None and state["mesh"]["n_shards"] == 4

eng2 = ContinuousBatchingEngine(params, cfg, n_slots=4, max_len=48,
                                mode="distilled", mesh=mesh)
eng2.warmup(tuple(pl for pl, _ in LENS))
restore_engine(eng2, state)
while eng2.has_work:
    eng2.step()
got = {r.rid: list(r.tokens) for r in eng2.finished}
assert got == base, (got, base)

# sharded snapshot -> single-device engine: refused
single = ContinuousBatchingEngine(params, cfg, n_slots=4, max_len=48,
                                  mode="distilled")
try:
    restore_engine(single, state)
    raise SystemExit("mesh-layout mismatch not rejected")
except ValueError as e:
    assert "mesh" in str(e)

# format-1 snapshot (no mesh metadata) -> sharded engine: refused
old = {k: v for k, v in state.items() if k != "mesh"}
old["format"] = 1
try:
    restore_engine(eng2, old)
    raise SystemExit("format-1 restore into sharded engine not rejected")
except ValueError as e:
    assert "format-1" in str(e)

# slot count must divide across the shards
try:
    ContinuousBatchingEngine(params, cfg, n_slots=3, max_len=48,
                             mode="distilled", mesh=make_slot_mesh(2))
    raise SystemExit("non-divisible n_slots not rejected")
except ValueError as e:
    assert "divide" in str(e)
""")


# ---------------------------------------------------------------------------
# fast single-device pieces
# ---------------------------------------------------------------------------
def _tiny_cfg(name="shard-scatter"):
    return ModelConfig(name=name, family="lcsm", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                       vocab=64, act="gelu", norm="layernorm",
                       pattern=(HYENA,),
                       hyena=HyenaConfig(n_filter_heads=2, filter_order=16,
                                         filter_emb=9, distill_order=8),
                       max_seq=512, dtype="float32")


def _trees_equal(a, b):
    return all(bool(jnp.array_equal(x, y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_write_cache_slots_dummy_rows_never_touch_the_pool():
    """The batch-admission scatter must drop dummy rows by EXPLICIT mask.
    Regression: with `.at[...].set(mode="drop")` the engine-side convention
    (dummy rows point at slot index n_slots) relied on out-of-bounds scatter
    semantics, which are not partition-stable — under a sharded pool each
    partition sees shifted local indices, so a dummy row could clobber slot
    0. A pure-dummy write must be a no-op, and mixed writes must touch only
    their real slots."""
    cfg = _tiny_cfg()
    B, L = 4, 32
    pool, _ = unzip(init_cache(cfg, B, L, per_slot=True))
    pool = jax.tree.map(
        lambda x: (jnp.arange(x.size, dtype=x.dtype).reshape(x.shape)
                   if jnp.issubdtype(x.dtype, jnp.floating) else x), pool)
    mk = lambda K: jax.tree.map(  # noqa: E731 — K-row batch of sevens
        lambda x: jnp.full_like(x, 7),
        unzip(init_cache(cfg, K, L, per_slot=True))[0])

    # every row dummy (slot index == n_slots): the pool must be untouched
    out = write_cache_slots(pool, mk(2), jnp.array([B, B], jnp.int32))
    assert _trees_equal(out, pool)
    # negative indices are dummies too
    out = write_cache_slots(pool, mk(1), jnp.array([-1], jnp.int32))
    assert _trees_equal(out, pool)

    # mixed: row 0 -> slot 0 is written, the dummy row must not clobber
    # slot 0 (the old mode="drop" bug) nor any other slot
    out = write_cache_slots(pool, mk(2), jnp.array([0, B], jnp.int32))
    rows = gather_cache_rows(out, jnp.arange(B))
    want0 = gather_cache_rows(mk(2), jnp.array([0]))
    got0 = gather_cache_rows(out, jnp.array([0]))
    assert _trees_equal(got0, want0)
    rest = gather_cache_rows(out, jnp.arange(1, B))
    rest_ref = gather_cache_rows(pool, jnp.arange(1, B))
    assert _trees_equal(rest, rest_ref)
    assert rows is not None

    # duplicate indices: a dummy duplicate of a real slot must lose
    out = write_cache_slots(pool, mk(2), jnp.array([1, 1], jnp.int32))
    got1 = gather_cache_rows(out, jnp.array([1]))
    assert _trees_equal(got1, gather_cache_rows(mk(2), jnp.array([1])))


def test_spec_window_syncs_is_a_resettable_resilience_counter():
    from repro.serve.metrics import RESILIENCE_KEYS, ResilienceCounters
    assert "spec_window_syncs" in RESILIENCE_KEYS
    c = ResilienceCounters()
    c.bump("spec_window_syncs", 3)
    assert c.get("spec_window_syncs") == 3
    assert c.snapshot()["spec_window_syncs"] == 3
    c.reset()
    assert c.get("spec_window_syncs") == 0
    assert "spec_window_syncs" in c.snapshot()   # stable BENCH columns


def test_sync_spec_len_bumps_stats_and_resilience():
    from repro.serve.scheduler import ContinuousBatchingEngine
    cfg = _tiny_cfg("shard-syncctr")
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=32)
    eng._spec_win[0] = 2                  # dirty the host mirror
    eng._sync_spec_len()
    assert eng.stats["spec_window_syncs"] == 1
    assert eng.resilience.get("spec_window_syncs") == 1
    eng._sync_spec_len()                  # clean: no upload, no bump
    assert eng.stats["spec_window_syncs"] == 1


def test_checkpoint_format2_single_device_and_format1_compat():
    """A single-device snapshot is format 2 with mesh=None, and a legacy
    format-1 snapshot (no mesh entry) still restores on a single device."""
    import pickle

    from repro.serve.checkpoint import restore_engine, save_engine
    from repro.serve.scheduler import ContinuousBatchingEngine
    cfg = _tiny_cfg("shard-ckpt1")
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=32)
    rng = np.random.default_rng(0)
    eng.submit(rng.integers(0, cfg.vocab, size=5).astype(np.int32),
               max_new_tokens=6)
    eng.step()
    # roundtrip: the live dict shares Request objects with the engine
    state = pickle.loads(pickle.dumps(save_engine(eng)))
    assert state["format"] == 2 and state["mesh"] is None

    legacy = {k: v for k, v in state.items() if k != "mesh"}
    legacy["format"] = 1
    eng2 = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=32)
    restore_engine(eng2, legacy)          # must not raise
    while eng2.has_work:
        eng2.step()
    eng.run()
    assert ([list(r.tokens) for r in eng2.finished]
            == [list(r.tokens) for r in eng.finished])

    bad = dict(state, format=99)
    with pytest.raises(ValueError, match="format"):
        restore_engine(eng2, bad)
