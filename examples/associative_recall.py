"""Associative recall with multi-head Hyena (paper Thm 4.1 / App. E.1).

  PYTHONPATH=src python examples/associative_recall.py

Trains two 2-layer models on the key-value recall task and compares accuracy:
  * MultiHyena with M=4 heads using the literal Sec.-4 outer-product operator
  * single-head Hyena (elementwise gating)
The multi-head model should reach higher accuracy at matched width — the
empirical support for Theorem 4.1 (Table E.1).
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.hyena import fft_conv, outer_product_op
from repro.models.layers import apply_norm, init_norm
from repro.distributed.sharding import Param, unzip
from repro.optim.adamw import adamw_init, adamw_update

VOCAB = 24           # keys + values
D, L, HEADS = 32, 64, 4
STEPS, BATCH = 400, 32


def make_batch(key, batch):
    """Sequences of (k1 v1 k2 v2 ... q) with q one of the seen keys."""
    n_pairs = (L - 1) // 2
    kk, kv, kq = jax.random.split(key, 3)
    keys = jax.random.randint(kk, (batch, n_pairs), 0, VOCAB // 2)
    vals = jax.random.randint(kv, (batch, n_pairs), VOCAB // 2, VOCAB)
    qi = jax.random.randint(kq, (batch,), 0, n_pairs)
    seq = jnp.zeros((batch, L), jnp.int32)
    seq = seq.at[:, 0:2 * n_pairs:2].set(keys)
    seq = seq.at[:, 1:2 * n_pairs:2].set(vals)
    query = jnp.take_along_axis(keys, qi[:, None], axis=1)[:, 0]
    target = jnp.take_along_axis(vals, qi[:, None], axis=1)[:, 0]
    seq = seq.at[:, -1].set(query)
    return seq, target


def init_model(key, heads):
    ks = jax.random.split(key, 8)
    scale = 1 / np.sqrt(D)
    p = {
        "emb": jnp.asarray(0.02) * jax.random.normal(ks[0], (VOCAB, D)),
        "out": scale * jax.random.normal(ks[6], (D, VOCAB)),
    }
    for l in (0, 1):
        p[f"wq{l}"] = scale * jax.random.normal(ks[1 + 2 * l], (D, D))
        p[f"wk{l}"] = scale * jax.random.normal(ks[2 + 2 * l], (D, D))
        p[f"wv{l}"] = scale * jax.random.normal(ks[5 + l], (D, D))
        p[f"wo{l}"] = scale * jax.random.normal(ks[7], (D, D))
        p[f"h{l}"] = 0.1 * jax.random.normal(ks[7], (heads, L))
    return p


def forward(p, seq, heads):
    x = p["emb"][seq]
    for l in (0, 1):
        q = x @ p[f"wq{l}"]
        k = x @ p[f"wk{l}"]
        v = x @ p[f"wv{l}"]
        if heads > 1:
            y = outer_product_op(q, k, v, p[f"h{l}"], heads)
        else:
            y = q * fft_conv(k * v, p[f"h{l}"])
        x = x + y @ p[f"wo{l}"]
    return x[:, -1, :] @ p["out"]


def train(heads, seed=0):
    p = init_model(jax.random.PRNGKey(seed), heads)
    opt = adamw_init(p)

    @jax.jit
    def step(p, opt, seq, tgt, i):
        def loss_fn(p):
            logits = forward(p, seq, heads)
            return jnp.mean(jax.nn.logsumexp(logits, -1) -
                            jnp.take_along_axis(logits, tgt[:, None], 1)[:, 0])
        loss, g = jax.value_and_grad(loss_fn)(p)
        p, opt, _ = adamw_update(g, opt, p, lr=3e-3, weight_decay=0.0)
        return p, opt, loss

    key = jax.random.PRNGKey(seed + 100)
    for i in range(STEPS):
        key, sub = jax.random.split(key)
        seq, tgt = make_batch(sub, BATCH)
        p, opt, loss = step(p, opt, seq, tgt, i)
    # eval
    seq, tgt = make_batch(jax.random.PRNGKey(999), 256)
    acc = float(jnp.mean(jnp.argmax(forward(p, seq, heads), -1) == tgt))
    return acc, float(loss)


if __name__ == "__main__":
    acc_multi, _ = train(heads=HEADS)
    acc_single, _ = train(heads=1)
    print(f"associative recall (vocab {VOCAB}, len {L}, width {D}):")
    print(f"  MultiHyena ({HEADS} heads, outer-product op): acc = {acc_multi:.2%}")
    print(f"  single-head Hyena (elementwise):              acc = {acc_single:.2%}")
    assert acc_multi >= acc_single - 0.05
