"""Per-architecture smoke tests: one forward + train step on a reduced config,
asserting output shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.distributed.sharding import unzip
from repro.models.model import (decode_step, forward, init_params, prefill,
                                train_loss)
from repro.optim.adamw import adamw_init, adamw_update

ARCHS = sorted(list_archs())

# tier-1 runs one arch per family (LCSM / dense attention / SSM / hybrid);
# the full 14-arch matrix is tier-2 (`-m slow` / make test-all).
FAST_ARCHS = {"multihyena-153m", "llama3.2-3b", "mamba2-130m",
              "recurrentgemma-9b"}
ARCHS_TIERED = [pytest.param(a, marks=() if a in FAST_ARCHS
                             else pytest.mark.slow) for a in ARCHS]


def _setup(arch, dtype="bfloat16"):
    cfg = smoke_config(get_config(arch)).replace(dtype=dtype)
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    fe = None
    if cfg.frontend != "none":
        fe = jnp.ones((2, cfg.frontend_len, cfg.d_model), jnp.bfloat16) * 0.01
    return cfg, params, toks, fe


@pytest.mark.parametrize("arch", ARCHS_TIERED)
def test_forward_shapes_no_nan(arch):
    cfg, params, toks, fe = _setup(arch)
    logits, aux = forward(params, toks, cfg, frontend=fe)
    S = 32 + (fe.shape[1] if (fe is not None and not cfg.enc_dec) else 0)
    assert logits.shape == (2, S, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_improves(arch):
    """One gradient step reduces loss on the same batch (sanity of grads)."""
    cfg, params, toks, fe = _setup(arch, dtype="float32")
    batch = {"tokens": toks}
    if fe is not None:
        batch["frontend"] = fe

    def loss_fn(p):
        return train_loss(p, batch, cfg)[0]

    l0, g = jax.value_and_grad(loss_fn)(params)
    assert not bool(jnp.isnan(l0))
    opt = adamw_init(params)
    params2, _, _ = adamw_update(g, opt, params, lr=1e-2, weight_decay=0.0)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0), (arch, float(l0), float(l1))


@pytest.mark.parametrize("arch", ARCHS_TIERED)
def test_prefill_decode_runs(arch):
    cfg, params, toks, fe = _setup(arch)
    cache, last = prefill(params, toks, cfg, max_len=64, frontend=fe)
    cache2, lg = decode_step(params, cache, toks[:, :1], cfg)
    assert lg.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.isnan(lg.astype(jnp.float32)).any())
    # cache structure is stable across steps (required by the decode loop)
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)
