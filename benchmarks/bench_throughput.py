"""Fig 1.1: generation throughput across batch sizes, plus the
continuous-batching request-stream benchmark.

Static-batch rows: Transformer (kv cache) vs Hyena cached-conv (Lemma 2.1)
vs LaughingHyena (distilled recurrence), prompt 128 / generate 64 — all three
through the same fully-jitted `generate_scanned` loop.

Request-stream rows (`stream_main`, suite "serve_stream"): Poisson arrivals
with mixed prompt lengths through the continuous-batching scheduler; reports
tokens/s and p50/p99 end-to-end latency per deployment mode (distilled,
cached_conv, attention kv).

Chaos rows (`chaos_main`, suite "serve_chaos", `make bench-chaos`): the same
request stream under the standard seeded fault schedule (CHAOS_SCHEDULE) —
state/conv/seq corruption, an injected dispatch fault, a host-loop stall and
a forced deadline expiry. Reports completion counts and the engine's
resilience counters; `check_regression --chaos` fails if any request never
reached a terminal status (recovered-fault counts are report-only). The
`distilled_drift` row runs a separate schedule (DRIFT_SCHEDULE) that
silently sign-flips one slot's modal state — invisible to the norm-margin
health guard — and checks the online drift sentinel catches it and demotes
the engine to the exact epoched-FFT path.

Drift rows (`serve_stream.error_vs_length` + `serve_stream.sentinel`):
teacher-forced next-token divergence of the distilled recurrence vs the
exact epoch path at growing prompt horizons, against the static truncation
certificate (`check_regression --drift` gates measured <= scale * bound),
and the sentinel's saturated-decode overhead (gated <= 2%, zero steady-state
compiles — every shadow-path executable is warmed in warmup()).
Scaling rows (`serve_stream.scaling`): saturated-decode throughput of the
sharded slot pool vs device count. Device counts are forced host (CPU)
devices, so the curve verifies layout/overhead scaling (no cross-shard
chatter, zero steady-state compiles), not hardware speedup — each
subprocess sets --xla_force_host_platform_device_count before importing
jax, which is why the sweep cannot run in this process.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from benchmarks.models import build, hyena_cfg, transformer_cfg
from repro.serve.engine import GenerationEngine
from repro.serve.scheduler import (ContinuousBatchingEngine,
                                   measure_saturated_decode,
                                   run_request_stream,
                                   synthesize_request_stream)

T_PROMPT, K_GEN = 128, 64


def _throughput_engine(cfg, params, batch, mode="distilled"):
    eng = GenerationEngine(params, cfg, max_len=T_PROMPT + K_GEN, mode=mode)
    prompt = jnp.ones((batch, T_PROMPT), jnp.int32)

    def run():
        return eng.generate_scanned(jax.random.PRNGKey(0), prompt, K_GEN)

    dt = timeit(run, warmup=1, iters=3)
    return batch * K_GEN / dt, dt


def main(out):
    tcfg = transformer_cfg()
    tparams = build(tcfg)
    hcfg = hyena_cfg()
    hparams = build(hcfg, distill=True)
    for batch in (1, 8, 32):
        tp, dt = _throughput_engine(tcfg, tparams, batch)
        out(row(f"fig1.1/transformer_kv/b{batch}", dt * 1e6,
                f"tok_s={tp:.0f}"))
        tp, dt = _throughput_engine(hcfg, hparams, batch)
        out(row(f"fig1.1/laughinghyena/b{batch}", dt * 1e6, f"tok_s={tp:.0f}"))
        tp, dt = _throughput_engine(hcfg, hparams, batch, mode="cached_conv")
        out(row(f"fig1.1/hyena_cached_conv/b{batch}", dt * 1e6,
                f"tok_s={tp:.0f}"))


# ---------------------------------------------------------------------------
# Request-stream serving benchmark (continuous batching)
# ---------------------------------------------------------------------------
N_REQ, RATE = 16, 40.0
PROMPT_LENS = (32, 48, 64, 96, 128)     # 5 distinct lengths, 3 buckets
GEN_TOKENS = (16, 48)
N_SLOTS, MAX_LEN = 4, 192
PREFILL_BATCH = 2
SPEC_K = "auto"                         # speculative case: autotuned config
SCALE_DEVICES = (1, 2, 4)               # slot-pool shard sweep (CPU mesh)
SCALE_SLOTS = 8                         # divisible by every count above


def _stream_case(cfg, params, mode, spec_k=0):
    from repro.serve.metrics import count_compiles, speculative_summary
    eng = ContinuousBatchingEngine(params, cfg, n_slots=N_SLOTS,
                                   max_len=MAX_LEN, mode=mode,
                                   max_prefills_per_step=PREFILL_BATCH,
                                   spec_k=spec_k)
    eng.warmup(PROMPT_LENS)
    stream = synthesize_request_stream(
        np.random.default_rng(0), N_REQ, rate=RATE, prompt_lens=PROMPT_LENS,
        gen_tokens=GEN_TOKENS, vocab=cfg.vocab)
    with count_compiles() as scope:
        m = run_request_stream(eng, stream)
    cs = eng.prefill_compile_stats()
    m["prefill_executables"] = cs["prefill_executables"]
    m["n_buckets"] = len(cs["buckets_used"])
    m["steady_state_compiles"] = scope.compiles
    m["prefill_calls"] = eng.stats["prefill_calls"]
    m["prefills"] = eng.stats["prefills"]
    if eng._spec:
        m.update(speculative_summary(eng.stats))
        m["spec_k"] = eng._spec_k
        m["draft_order"] = eng.draft_order
        m["spec_branch"] = eng._spec_branch
    if eng.spec_report is not None:
        m["autotune"] = eng.spec_report.table()
        m["spec_enabled"] = eng.spec_report.chosen is not None
    # saturated-decode throughput: every slot busy, pure decode ticks. The
    # Poisson stream's decode_tok_per_s is arrival-diluted and noisy; THIS
    # is the number check_regression gates the spec-vs-plain comparison on.
    # Measured after (outside) the compile-count scope.
    sat = measure_saturated_decode(eng, prompt_len=32)
    m["decode_sat_tok_per_s"] = sat["decode_tok_per_s"]
    if sat["acceptance"] is not None:
        m["sat_acceptance"] = sat["acceptance"]
    if sat["tokens_per_slot_round"] is not None:
        m["sat_tokens_per_slot_round"] = sat["tokens_per_slot_round"]
    return m


# ---------------------------------------------------------------------------
# Observability overhead: saturated decode with telemetry on vs off
# ---------------------------------------------------------------------------
SERVE_TRACE_OUT = "BENCH_serve_trace.json"   # uploaded by the bench-serve job


def _observability_case(cfg, params):
    """Measure the cost of the telemetry layer (metrics registry + span
    tracer, both fully enabled) against a telemetry-dark engine
    (MetricsRegistry(enabled=False), null tracer) on saturated decode.
    check_regression gates the overhead at <= 2% with zero steady-state
    compiles. Both engines share the jit memo, so the comparison is pure
    host-side overhead; measurements interleave off/on twice and keep each
    side's best to cancel drift, which on a noisy CPU runner matters more
    than the overhead itself. The traced run's spans are saved to
    SERVE_TRACE_OUT as the nightly trace artifact."""
    from repro.serve.metrics import MetricsRegistry, count_compiles
    from repro.serve.trace import Tracer
    dark = ContinuousBatchingEngine(
        params, cfg, n_slots=N_SLOTS, max_len=MAX_LEN, mode="distilled",
        max_prefills_per_step=PREFILL_BATCH,
        metrics=MetricsRegistry(enabled=False))
    tracer = Tracer()
    lit = ContinuousBatchingEngine(
        params, cfg, n_slots=N_SLOTS, max_len=MAX_LEN, mode="distilled",
        max_prefills_per_step=PREFILL_BATCH, tracer=tracer)
    dark.warmup(PROMPT_LENS)
    lit.warmup(PROMPT_LENS)
    off = on = 0.0
    compiles = 0
    for _ in range(2):
        off = max(off, measure_saturated_decode(
            dark, prompt_len=32)["decode_tok_per_s"])
        with count_compiles() as scope:
            on = max(on, measure_saturated_decode(
                lit, prompt_len=32)["decode_tok_per_s"])
        compiles += scope.compiles
    tracer.save(SERVE_TRACE_OUT)
    return {
        "decode_sat_tok_per_s_off": off,
        "decode_sat_tok_per_s_on": on,
        # positive = telemetry made saturated decode slower
        "overhead_frac": (off - on) / off if off > 0 else 0.0,
        "steady_state_compiles": compiles,
        "trace_events": len(tracer),
        "trace_dropped": tracer.dropped,
        "trace_file": SERVE_TRACE_OUT,
        "metric_series": len(lit.metrics.names()),
    }


# ---------------------------------------------------------------------------
# Distillation error vs horizon + sentinel overhead
# ---------------------------------------------------------------------------
ERROR_HORIZONS = (32, 64, 128, 192)     # last == MAX_LEN
SENTINEL_EVERY = 64                     # saturated-decode window ~= 1 check


def _log_softmax(x):
    x = x - x.max()
    return x - np.log(np.exp(x).sum())


def _error_vs_length_case(cfg, params):
    """Teacher-forced next-token divergence (max |log-softmax| gap) of the
    distilled recurrence vs the exact epoched-FFT path on one random prompt,
    at growing horizons, next to the static truncation certificate. The
    epoch path IS the exact convolution (token-identity is tested), so this
    measures pure distillation error — the serving-level realization of the
    paper's Fig. 4.2 error-vs-length curves.

    Prefill computes the exact convolution in EVERY cache kind (that is the
    point of prefill), so the distilled side must route its last token
    through the recurrent decode step: native-prefill L-1 tokens, decode
    token L-1. The exact side epoch-prefills all L tokens."""
    from repro.core.distill import distillation_certificate
    from repro.serve.engine import jitted_decode_step, jitted_prefill
    rng = np.random.default_rng(0)
    seq = rng.integers(0, cfg.vocab, size=MAX_LEN).astype(np.int32)
    p_exact = jitted_prefill(cfg, MAX_LEN, "epoch")
    p_dist = jitted_prefill(cfg, MAX_LEN, "native")
    decode = jitted_decode_step(cfg)
    pts = []
    for L in ERROR_HORIZONS:
        _, exact = p_exact(params, jnp.asarray(seq[None, :L]))
        cache, _ = p_dist(params, jnp.asarray(seq[None, :L - 1]))
        _, approx = decode(params, cache,
                           jnp.asarray(seq[None, L - 1:L]))
        e = _log_softmax(np.asarray(exact[0], np.float64))
        a = _log_softmax(np.asarray(approx[0, 0], np.float64))
        pts.append({"len": int(L),
                    "logit_div": float(np.max(np.abs(e - a)))})
    cert = distillation_certificate(params, cfg, MAX_LEN)
    return {"horizons": pts,
            "certificate_total_l1": cert["total_l1"],
            "certificate_layers": cert["layers"],
            "certificate_horizon": cert["horizon"]}


def _sentinel_case(cfg, params):
    """Saturated decode with the drift sentinel on vs off (same off/on
    interleave-and-keep-best protocol as _observability_case). The sentinel
    engine's shadow executables are warmed in warmup(), so the compile scope
    around the measured window must stay at zero."""
    from repro.serve.metrics import count_compiles
    base = ContinuousBatchingEngine(
        params, cfg, n_slots=N_SLOTS, max_len=MAX_LEN, mode="distilled",
        max_prefills_per_step=PREFILL_BATCH)
    sent = ContinuousBatchingEngine(
        params, cfg, n_slots=N_SLOTS, max_len=MAX_LEN, mode="distilled",
        max_prefills_per_step=PREFILL_BATCH,
        drift_check_every=SENTINEL_EVERY)
    base.warmup(PROMPT_LENS)
    sent.warmup(PROMPT_LENS)
    off = on = 0.0
    compiles = 0
    for _ in range(2):
        off = max(off, measure_saturated_decode(
            base, prompt_len=32)["decode_tok_per_s"])
        with count_compiles() as scope:
            on = max(on, measure_saturated_decode(
                sent, prompt_len=32)["decode_tok_per_s"])
        compiles += scope.compiles
    h = sent.metrics.get("serve_drift_logit_div")
    return {
        "decode_sat_tok_per_s_off": off,
        "decode_sat_tok_per_s_on": on,
        "overhead_frac": (off - on) / off if off > 0 else 0.0,
        "steady_state_compiles": compiles,
        "drift_check_every": SENTINEL_EVERY,
        "drift_checks": sent.resilience.get("drift_checks"),
        "drift_max": float(h._max) if h.count else None,
    }


# run in a fresh interpreter per device count: the device count is fixed
# before jax imports. Prints one "RESULT {json}" line on success.
_SCALE_SNIPPET = """
import json
import jax, numpy as np
from benchmarks.bench_throughput import MAX_LEN, SCALE_SLOTS
from benchmarks.models import build, hyena_cfg
from repro.launch.mesh import make_slot_mesh
from repro.serve.metrics import count_compiles
from repro.serve.scheduler import (ContinuousBatchingEngine,
                                   measure_saturated_decode)

d = {devices}
cfg = hyena_cfg()
params = build(cfg, distill=True)
mesh = make_slot_mesh(d) if d > 1 else None
eng = ContinuousBatchingEngine(params, cfg, n_slots=SCALE_SLOTS,
                               max_len=MAX_LEN, mode="distilled", mesh=mesh)
eng.warmup((32,))
with count_compiles() as scope:
    m = measure_saturated_decode(eng, prompt_len=32)
print("RESULT " + json.dumps({{
    "devices": d,
    "n_shards": eng._n_shards,
    "decode_sat_tok_per_s": m["decode_tok_per_s"],
    "steady_state_compiles": scope.compiles,
}}))
"""


def _scale_case(devices: int):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.pathsep.join(
            p for p in (root, os.path.join(root, "src"),
                        os.environ.get("PYTHONPATH")) if p))
    env.pop("REPRO_SLOT_MESH", None)
    p = subprocess.run([sys.executable, "-c",
                        _SCALE_SNIPPET.format(devices=devices)],
                       capture_output=True, text=True, env=env, timeout=1200)
    for line in reversed(p.stdout.splitlines()):
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    tail = (p.stdout + p.stderr)[-2000:]
    return {"devices": devices, "error": f"rc={p.returncode}: {tail}"}


def stream_main(out):
    hcfg = hyena_cfg()
    hparams = build(hcfg, distill=True)
    tcfg = transformer_cfg()
    tparams = build(tcfg)
    results = {"prompt_lens": list(PROMPT_LENS), "n_requests": N_REQ,
               "rate_req_s": RATE, "n_slots": N_SLOTS,
               "prefill_batch": PREFILL_BATCH, "modes": {}}
    for label, cfg, params, mode, spec in (
            ("distilled", hcfg, hparams, "distilled", 0),
            ("distilled_spec", hcfg, hparams, "distilled", SPEC_K),
            ("cached_conv", hcfg, hparams, "cached_conv", 0),
            ("epoch", hcfg, hparams, "epoch", 0),
            ("attention_kv", tcfg, tparams, "distilled", 0)):
        m = _stream_case(cfg, params, mode, spec_k=spec)
        results["modes"][label] = m
        extra = ""
        if "spec_k" in m:
            extra = (f" spec=k{m['spec_k']}/d{m['draft_order']}"
                     f"/b{m['spec_branch']}")
            if m.get("acceptance_rate") is not None:
                extra += f" acc={m['acceptance_rate']:.2f}"
            if m.get("tokens_per_slot_round") is not None:
                extra += f" tok_per_round={m['tokens_per_slot_round']:.2f}"
        elif spec:
            extra = " spec=off(autotune)"
        out(row(f"serve_stream/{label}", m["wall_s"] * 1e6,
                f"tok_s={m['tok_per_s']:.0f} "
                f"decode_tok_s={m['decode_tok_per_s']:.0f} "
                f"sat_decode_tok_s={m['decode_sat_tok_per_s']:.0f} "
                f"p50_ms={m['p50_latency_s'] * 1e3:.1f} "
                f"p99_ms={m['p99_latency_s'] * 1e3:.1f} "
                f"p50_ttft_ms={m['p50_ttft_s'] * 1e3:.1f} "
                f"p99_ttft_ms={m['p99_ttft_s'] * 1e3:.1f} "
                f"prefill_exec={m['prefill_executables']}"
                f"/{len(PROMPT_LENS)}lens "
                f"compiles_in_run={m['steady_state_compiles']}" + extra))
    # telemetry-on vs telemetry-off saturated decode (the <= 2% overhead
    # gate) + the Chrome-trace artifact the CI job uploads
    obs = _observability_case(hcfg, hparams)
    results["observability"] = obs
    out(row("serve_stream/observability", 0.0,
            f"sat_decode_tok_s_on={obs['decode_sat_tok_per_s_on']:.0f} "
            f"off={obs['decode_sat_tok_per_s_off']:.0f} "
            f"overhead={obs['overhead_frac'] * 100:+.2f}% "
            f"compiles_in_run={obs['steady_state_compiles']} "
            f"trace_events={obs['trace_events']} "
            f"metric_series={obs['metric_series']}"))
    # distillation error vs horizon against the static certificate (the
    # check_regression --drift gate) + the sentinel's overhead gate
    evl = _error_vs_length_case(hcfg, hparams)
    results["error_vs_length"] = evl
    out(row("serve_stream/error_vs_length", 0.0,
            " ".join(f"L{p['len']}={p['logit_div']:.3e}"
                     for p in evl["horizons"])
            + f" cert_l1={evl['certificate_total_l1']:.3e}"))
    sent = _sentinel_case(hcfg, hparams)
    results["sentinel"] = sent
    out(row("serve_stream/sentinel", 0.0,
            f"sat_decode_tok_s_on={sent['decode_sat_tok_per_s_on']:.0f} "
            f"off={sent['decode_sat_tok_per_s_off']:.0f} "
            f"overhead={sent['overhead_frac'] * 100:+.2f}% "
            f"checks={sent['drift_checks']} "
            f"compiles_in_run={sent['steady_state_compiles']}"))
    # tok/s-vs-devices scaling of the sharded slot pool (fresh interpreter
    # per device count — see _SCALE_SNIPPET)
    scaling = [_scale_case(d) for d in SCALE_DEVICES]
    results["scaling"] = {"n_slots": SCALE_SLOTS, "devices": scaling}
    for s in scaling:
        if "error" in s:
            out(row(f"serve_stream/scaling/d{s['devices']}", 0.0,
                    f"ERROR {s['error'][:120]}"))
        else:
            out(row(f"serve_stream/scaling/d{s['devices']}", 0.0,
                    f"sat_decode_tok_s={s['decode_sat_tok_per_s']:.0f} "
                    f"shards={s['n_shards']} "
                    f"compiles_in_run={s['steady_state_compiles']}"))
    return {"serve_stream": results}


# ---------------------------------------------------------------------------
# Chaos benchmark: the request stream under a standard fault schedule
# ---------------------------------------------------------------------------
# One seeded schedule exercises every recovery path: NaN/Inf corruption of
# the modal state, the conv tail, and the sequence buffers (quarantine +
# re-prefill), an injected dispatch fault, a host-loop stall long enough to
# trip the watchdog, and a forced deadline expiry. Tick numbers sit inside
# the stream's busy window at the settings above so each event finds a
# resident slot to hit.
CHAOS_SCHEDULE = {
    "seed": 0,
    "events": [
        {"tick": 4, "kind": "corrupt", "where": "state", "value": "nan"},
        {"tick": 8, "kind": "raise"},
        {"tick": 12, "kind": "corrupt", "where": "conv", "value": "inf"},
        {"tick": 16, "kind": "stall", "duration_s": 0.05},
        {"tick": 20, "kind": "expire"},
        {"tick": 24, "kind": "corrupt", "where": "seq", "value": "nan"},
    ],
}
CHAOS_WATCHDOG_S = 0.02
CHAOS_SPEC_K = 4        # fixed config: the autotune sweep is not under test

# Silent-drift schedule for the sentinel demotion row: value=-2.0 scales the
# modal state by (1 + eps) = -1 — a pure sign flip. The norm-margin health
# guard cannot see it (norms are unchanged) but the decoded distribution is
# garbage, which is exactly the failure class the shadow-verify sentinel
# exists for. The row runs on `sentinel_cfg()` (near-exact distillation):
# the sentinel can only flag drift larger than the genuine distillation
# error, so the tolerance must sit between the clean shadow divergence
# (~1e-2 on that model) and the flipped-state divergence (~2+); the
# bench-size model's loose certificate (serve_stream.error_vs_length)
# leaves no such gap.
DRIFT_SCHEDULE = {
    "seed": 0,
    "events": [{"tick": 8, "kind": "drift", "value": -2.0}],
}
DRIFT_CHECK_EVERY = 4
DRIFT_TOL = 0.5
DRIFT_MAX_LEN = 48
DRIFT_PROMPT_LENS = (8, 16)
DRIFT_GEN_TOKENS = (8, 12)


CHAOS_TRACE_OUT = "BENCH_chaos_trace.json"  # uploaded by the nightly job


def _chaos_case(cfg, params, mode, spec_k=0, tracer=None):
    from repro.serve.faults import FaultInjector
    inj = FaultInjector(CHAOS_SCHEDULE["events"], seed=CHAOS_SCHEDULE["seed"])
    eng = ContinuousBatchingEngine(params, cfg, n_slots=N_SLOTS,
                                   max_len=MAX_LEN, mode=mode,
                                   max_prefills_per_step=PREFILL_BATCH,
                                   spec_k=spec_k, fault_injector=inj,
                                   watchdog_s=CHAOS_WATCHDOG_S,
                                   tracer=tracer)
    eng.warmup(PROMPT_LENS)
    stream = synthesize_request_stream(
        np.random.default_rng(0), N_REQ, rate=RATE, prompt_lens=PROMPT_LENS,
        gen_tokens=GEN_TOKENS, vocab=cfg.vocab)
    m = run_request_stream(eng, stream)
    return {
        "n_requests_expected": N_REQ,
        "n_completed": int(m["n_requests"]),
        "n_ok": int(m["n_ok"]),
        "n_errors": int(m["n_errors"]),
        # requests that never reached a terminal status — the gated number
        "unrecovered": N_REQ - int(m["n_requests"]),
        "n_tokens": int(m["n_tokens"]),
        "wall_s": m["wall_s"],
        "tok_per_s": m["tok_per_s"],
        "faults_fired": len(inj.log),
        "recovery_events": len(eng.events),
        "total_faults": eng.resilience.total_faults,
        "resilience": m["resilience"],
    }


def _drift_chaos_case():
    """Distilled engine + silent state drift: the sentinel must raise the
    alarm and demote the engine to the exact epoch path, with every request
    still reaching a terminal status. Runs on the sentinel-calibrated small
    model (see DRIFT_SCHEDULE comment)."""
    from benchmarks.models import sentinel_cfg
    from repro.serve.faults import FaultInjector
    cfg = sentinel_cfg()
    params = build(cfg, distill=True, distill_len=DRIFT_MAX_LEN)
    inj = FaultInjector(DRIFT_SCHEDULE["events"], seed=DRIFT_SCHEDULE["seed"])
    eng = ContinuousBatchingEngine(params, cfg, n_slots=N_SLOTS,
                                   max_len=DRIFT_MAX_LEN, mode="distilled",
                                   max_prefills_per_step=PREFILL_BATCH,
                                   fault_injector=inj,
                                   drift_check_every=DRIFT_CHECK_EVERY,
                                   drift_tol=DRIFT_TOL)
    eng.warmup(DRIFT_PROMPT_LENS)
    stream = synthesize_request_stream(
        np.random.default_rng(0), N_REQ, rate=RATE,
        prompt_lens=DRIFT_PROMPT_LENS,
        gen_tokens=DRIFT_GEN_TOKENS, vocab=cfg.vocab)
    m = run_request_stream(eng, stream)
    h = eng.metrics.get("serve_drift_logit_div")
    return {
        "n_requests_expected": N_REQ,
        "n_completed": int(m["n_requests"]),
        "n_ok": int(m["n_ok"]),
        "n_errors": int(m["n_errors"]),
        "unrecovered": N_REQ - int(m["n_requests"]),
        "wall_s": m["wall_s"],
        "faults_fired": len(inj.log),
        "drift_checks": int(m["resilience"].get("drift_checks", 0)),
        "drift_alarms": int(m["resilience"].get("drift_alarms", 0)),
        "drift_max": float(h._max) if h.count else None,
        "drift_tol": DRIFT_TOL,
        "final_mode": eng.mode,
        "resilience": m["resilience"],
    }


def chaos_main(out):
    hcfg = hyena_cfg()
    hparams = build(hcfg, distill=True)
    tcfg = transformer_cfg()
    tparams = build(tcfg)
    results = {"schedule": CHAOS_SCHEDULE, "n_requests": N_REQ,
               "watchdog_s": CHAOS_WATCHDOG_S, "modes": {}}
    for label, cfg, params, mode, spec in (
            ("distilled", hcfg, hparams, "distilled", 0),
            ("distilled_spec", hcfg, hparams, "distilled", CHAOS_SPEC_K),
            ("cached_conv", hcfg, hparams, "cached_conv", 0),
            ("attention_kv", tcfg, tparams, "distilled", 0)):
        # trace the distilled case: its exported timeline shows each faulted
        # request's quarantine -> re-prefill -> retire arc (nightly artifact)
        tracer = None
        if label == "distilled":
            from repro.serve.trace import Tracer
            tracer = Tracer()
        m = _chaos_case(cfg, params, mode, spec_k=spec, tracer=tracer)
        if tracer is not None:
            tracer.save(CHAOS_TRACE_OUT)
            m["trace_file"] = CHAOS_TRACE_OUT
            m["trace_events"] = len(tracer)
        results["modes"][label] = m
        out(row(f"serve_chaos/{label}", m["wall_s"] * 1e6,
                f"completed={m['n_completed']}/{m['n_requests_expected']} "
                f"ok={m['n_ok']} errors={m['n_errors']} "
                f"unrecovered={m['unrecovered']} "
                f"faults_absorbed={m['total_faults']} "
                f"reprefills={m['resilience']['slot_reprefills']} "
                f"poisoned={m['resilience']['poisoned']}"))
    # silent-drift row: sentinel detection + demotion to the exact path
    m = _drift_chaos_case()
    results["modes"]["distilled_drift"] = m
    out(row("serve_chaos/distilled_drift", m["wall_s"] * 1e6,
            f"completed={m['n_completed']}/{m['n_requests_expected']} "
            f"unrecovered={m['unrecovered']} "
            f"drift_alarms={m['drift_alarms']}/{m['drift_checks']}checks "
            f"drift_max={m['drift_max'] if m['drift_max'] is not None else float('nan'):.3g} "
            f"final_mode={m['final_mode']}"))
    return {"serve_chaos": results}
