"""Kernel-path micro-benchmarks (CPU host): Lemma 3.1's O(dL) modal
evaluation vs the O~(L) rational-FFT evaluation (Lemma A.6), and the fused
decode-step math. Pallas wall-times require real TPU; interpret-mode numbers
are correctness-path only, so we time the equivalent-math jnp paths."""
import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import eval_filter, init_modal
from repro.core.transfer import impulse_from_tf, tf_from_modal
from repro.kernels.ssm_decode.ref import ssm_decode_ref


def main(out):
    ssm = init_modal(jax.random.PRNGKey(0), (64,), 8, r_minmax=(0.5, 0.9))
    for L in (2048, 16384):
        f1 = jax.jit(lambda s: eval_filter(s, L))
        dt = timeit(f1, ssm, warmup=1, iters=3)
        out(row(f"lemma3.1/modal_eval_O(dL)/L{L}", dt * 1e6, ""))
        a, b = tf_from_modal(ssm.poles(), ssm.residues(), ssm.h0)
        f2 = jax.jit(lambda a, b, h0: impulse_from_tf(a, b, h0, L))
        dt = timeit(f2, a, b, ssm.h0, warmup=1, iters=3)
        out(row(f"lemmaA.6/rational_fft_O(LlogL)/L{L}", dt * 1e6, ""))
    # fused decode step math at serving scale
    B, C, d = 32, 2048, 8
    args = (jax.random.normal(jax.random.PRNGKey(1), (B, C, d)),
            jax.random.normal(jax.random.PRNGKey(2), (B, C, d)),
            jax.random.normal(jax.random.PRNGKey(3), (B, C)),
            jnp.log(jnp.full((C, d), 0.9)), jnp.zeros((C, d)),
            jnp.ones((C, d)), jnp.zeros((C, d)), jnp.zeros((C,)))
    f3 = jax.jit(ssm_decode_ref)
    dt = timeit(f3, *args, warmup=2, iters=5)
    out(row(f"prop3.3/ssm_decode_step/B{B}xC{C}xd{d}", dt * 1e6,
            f"ns_per_state={dt*1e9/(B*C*d):.2f}"))
