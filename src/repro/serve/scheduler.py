"""Continuous-batching scheduler: a fixed pool of B state slots.

The paper's point of distilling Hyena filters into modal SSMs is O(1)
compute/memory per token at decode — which makes multi-request serving a
*slot* problem rather than a paged-KV problem: every request's entire decode
state is a fixed-size row of a pooled cache (modal SSM state, conv tail, or
kv/conv buffers for the baseline modes). This module schedules requests onto
those rows:

  * admission   — a queued request is prefilled (batch=1 forward) and its
                  cache scattered into a free slot (`write_cache_slot`);
  * decode      — ONE jitted `decode_step` over the full slot pool per tick,
                  each slot at its own position (per-slot `pos` vector);
                  inactive slots decode garbage that is ignored and fully
                  overwritten on readmission;
  * sampling    — per-slot temperature/top-k/top-p in one batched
                  `sample_token_slots` call;
  * eviction    — on EOS or max-new-tokens the slot is freed (and optionally
                  zeroed) and the next queued request admitted;
  * interleave  — at most `max_prefills_per_step` admissions happen per tick,
                  so resident requests keep decoding while a burst of
                  arrivals prefills.

Deployment modes (paper Sec. 2.2 / 5.4): "distilled" (LaughingHyena modal
recurrence), "cached_conv" (Lemma 2.1 O(t) baseline), and the native mode of
non-LCSM archs (attention KV cache, Mamba2/RG-LRU state).

Prompt lengths are prefilled at their exact length, so each distinct length
compiles one prefill executable (bucket prompt lengths upstream if that
matters); the pooled decode step compiles exactly once.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import unzip
from repro.models.layers import NOCTX, ShardCtx
from repro.models.model import (init_cache, materialize_conv_filters,
                                reset_cache_slot, write_cache_slot)
from repro.serve.sampling import sample_token, sample_token_slots

QUEUED, RUNNING, FINISHED = "queued", "running", "finished"

_SLOT_JITS: Dict[str, Callable] = {}


def _jitted_write_slot():
    if "write" not in _SLOT_JITS:
        _SLOT_JITS["write"] = jax.jit(write_cache_slot, donate_argnums=(0,))
    return _SLOT_JITS["write"]


def _jitted_reset_slot():
    if "reset" not in _SLOT_JITS:
        _SLOT_JITS["reset"] = jax.jit(reset_cache_slot, donate_argnums=(0,))
    return _SLOT_JITS["reset"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0       # <= 0 -> greedy
    top_k: int = 0                 # <= 0 -> disabled
    top_p: float = 1.0             # >= 1 -> disabled

GREEDY = SamplingParams()


@dataclasses.dataclass
class Request:
    """One generation request plus its lifecycle/latency bookkeeping."""
    rid: int
    prompt: np.ndarray                       # (T,) int32
    max_new_tokens: int
    sampling: SamplingParams = GREEDY
    eos_id: Optional[int] = None
    # --- filled by the engine ---
    tokens: List[int] = dataclasses.field(default_factory=list)
    status: str = QUEUED
    slot: int = -1
    finish_reason: str = ""
    t_submit: float = math.nan
    t_admitted: float = math.nan
    t_first_token: float = math.nan
    t_finished: float = math.nan

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def latency(self) -> float:
        return self.t_finished - self.t_submit

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit


class ContinuousBatchingEngine:
    """Slot-pool serving engine. See module docstring.

    `mode`: "distilled" | "cached_conv" (LCSM archs) — non-LCSM archs serve
    their native cache in either setting. `reset_on_evict` zeroes a slot on
    eviction (hygiene / debugging; admission overwrites the slot anyway).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 8,
                 max_len: int = 4096, mode: str = "distilled",
                 ctx: ShardCtx = NOCTX, seed: int = 0,
                 max_prefills_per_step: int = 1, reset_on_evict: bool = False,
                 clock: Callable[[], float] = time.monotonic):
        if mode not in ("distilled", "cached_conv"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "cached_conv" and cfg.hyena is None:
            raise ValueError("cached_conv mode requires a Hyena (LCSM) arch")
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.mode = mode
        self.ctx = ctx
        self.max_prefills_per_step = max_prefills_per_step
        self.reset_on_evict = reset_on_evict
        self._clock = clock
        self._key = jax.random.PRNGKey(seed)
        cache_kind = "conv" if mode == "cached_conv" else "native"
        self.cache, _ = unzip(init_cache(cfg, n_slots, max_len,
                                         cache_kind=cache_kind, per_slot=True))
        from repro.serve.engine import jitted_decode_step, jitted_prefill
        self._decode = jitted_decode_step(cfg, ctx)
        self._prefill = jitted_prefill(cfg, max_len, cache_kind, ctx)
        self._write_slot = _jitted_write_slot()
        self._reset_slot = _jitted_reset_slot()
        # cached-conv mode: materialize the long filters once, not per token
        self._conv_filters = (materialize_conv_filters(params, cfg, max_len)
                              if cache_kind == "conv" else None)
        # per-slot host-side state
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.active = np.zeros(n_slots, bool)
        self.last_token = np.zeros(n_slots, np.int32)
        self.temps = np.zeros(n_slots, np.float32)
        self.top_ks = np.zeros(n_slots, np.int32)
        self.top_ps = np.ones(n_slots, np.float32)
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self._next_rid = 0
        self.stats: Dict[str, int] = {"admitted": 0, "evicted": 0,
                                      "decode_steps": 0, "prefills": 0}

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int,
               sampling: SamplingParams = GREEDY,
               eos_id: Optional[int] = None, rid: Optional[int] = None
               ) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        req = Request(rid=self._next_rid if rid is None else rid,
                      prompt=prompt, max_new_tokens=max_new_tokens,
                      sampling=sampling, eos_id=eos_id)
        self._next_rid = max(self._next_rid, req.rid) + 1
        return self.submit_request(req)

    def submit_request(self, req: Request) -> Request:
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        w = (self.cfg.hyena.short_conv - 1) if self.cfg.hyena else 1
        if req.prompt_len < max(w, 1):
            raise ValueError(f"prompt shorter than the short-conv tail ({w})")
        if req.prompt_len + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request needs {req.prompt_len + req.max_new_tokens} "
                f"positions > max_len={self.max_len}")
        req.status = QUEUED
        req.t_submit = self._clock()
        self.queue.append(req)
        return req

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def n_free(self) -> int:
        return self.n_slots - self.n_active

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active > 0

    def _free_slot(self) -> Optional[int]:
        for b in range(self.n_slots):
            if not self.active[b]:
                return b
        return None

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def step(self) -> int:
        """One scheduler tick: admit up to max_prefills_per_step queued
        requests into free slots, then one pooled decode step. Returns the
        number of tokens emitted this tick."""
        admitted = 0
        while (self.queue and admitted < self.max_prefills_per_step
               and self._free_slot() is not None):
            self._admit(self.queue.popleft(), self._free_slot())
            admitted += 1
        emitted = admitted            # each admission emits its first token
        if self.n_active > 0:
            emitted += self._decode_all()
        return emitted

    def run(self) -> List[Request]:
        """Drain queue + residents to completion; returns finished requests."""
        while self.has_work:
            self.step()
        return self.finished

    def warmup(self, prompt_lens: Sequence[int]) -> None:
        """Compile the prefill executable for each prompt length and the
        pooled decode step, so a timed run measures steady-state serving.
        Side effect: idle slots advance one (ignored) decode position."""
        for L in sorted(set(int(x) for x in prompt_lens)):
            jax.block_until_ready(
                self._prefill(self.params, jnp.zeros((1, L), jnp.int32)))
        self.cache, _ = self._decode(self.params, self.cache,
                                     jnp.asarray(self.last_token)[:, None],
                                     conv_filters=self._conv_filters)
        jax.block_until_ready(self.cache)

    # ------------------------------------------------------------------
    def _admit(self, req: Request, slot: int) -> None:
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        cache1, logits = self._prefill(self.params, prompt)
        self.cache = self._write_slot(self.cache, cache1, slot)
        self.stats["prefills"] += 1
        self.stats["admitted"] += 1
        req.status = RUNNING
        req.slot = slot
        req.t_admitted = self._clock()
        self.slots[slot] = req
        self.active[slot] = True
        sp = req.sampling
        self.temps[slot] = sp.temperature
        self.top_ks[slot] = sp.top_k
        self.top_ps[slot] = sp.top_p
        # first generated token comes from the prefill logits (same
        # convention as GenerationEngine.generate)
        tok = sample_token(self._next_key(), logits,
                           temperature=sp.temperature, top_k=sp.top_k,
                           top_p=sp.top_p)
        self._append_token(slot, int(tok[0]))

    def _decode_all(self) -> int:
        toks = jnp.asarray(self.last_token)[:, None]
        self.cache, logits = self._decode(self.params, self.cache, toks,
                                          conv_filters=self._conv_filters)
        self.stats["decode_steps"] += 1
        nxt = sample_token_slots(self._next_key(), logits[:, 0, :],
                                 temperature=jnp.asarray(self.temps),
                                 top_k=jnp.asarray(self.top_ks),
                                 top_p=jnp.asarray(self.top_ps))
        nxt = np.asarray(nxt)
        emitted = 0
        for b in np.nonzero(self.active)[0]:
            self._append_token(int(b), int(nxt[b]))
            emitted += 1
        return emitted

    def _append_token(self, slot: int, tok: int) -> None:
        req = self.slots[slot]
        assert req is not None
        if math.isnan(req.t_first_token):
            req.t_first_token = self._clock()
        req.tokens.append(tok)
        self.last_token[slot] = tok
        if req.eos_id is not None and tok == req.eos_id:
            self._evict(slot, "eos")
        elif len(req.tokens) >= req.max_new_tokens:
            self._evict(slot, "max_tokens")

    def _evict(self, slot: int, reason: str) -> None:
        req = self.slots[slot]
        req.status = FINISHED
        req.finish_reason = reason
        req.t_finished = self._clock()
        req.slot = -1
        self.slots[slot] = None
        self.active[slot] = False
        self.temps[slot] = 0.0
        self.top_ks[slot] = 0
        self.top_ps[slot] = 1.0
        self.stats["evicted"] += 1
        self.finished.append(req)
        if self.reset_on_evict:
            self.cache = self._reset_slot(self.cache, slot)


# ---------------------------------------------------------------------------
# Request-stream workload: Poisson arrivals, mixed prompt lengths.
# ---------------------------------------------------------------------------
def synthesize_request_stream(rng: np.random.Generator, n_requests: int, *,
                              rate: float, prompt_lens: Sequence[int],
                              gen_tokens: Tuple[int, int], vocab: int,
                              sampling: SamplingParams = GREEDY,
                              eos_id: Optional[int] = None
                              ) -> List[Tuple[float, Request]]:
    """(arrival_time_s, Request) pairs: exponential inter-arrival gaps at
    `rate` req/s, prompt lengths drawn from `prompt_lens`, generation lengths
    uniform over [gen_tokens[0], gen_tokens[1]]."""
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.choice(np.asarray(prompt_lens)))
        n_gen = int(rng.integers(gen_tokens[0], gen_tokens[1] + 1))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        out.append((t, Request(rid=rid, prompt=prompt, max_new_tokens=n_gen,
                               sampling=sampling, eos_id=eos_id)))
    return out


def run_request_stream(engine: ContinuousBatchingEngine,
                       stream: Sequence[Tuple[float, Request]],
                       *, clock: Callable[[], float] = time.monotonic
                       ) -> Dict[str, float]:
    """Replay a timed request stream through the engine and report
    tokens/s plus p50/p99 end-to-end and first-token latency."""
    pending = sorted(stream, key=lambda p: p[0])
    t0 = clock()
    i = 0
    while i < len(pending) or engine.has_work:
        now = clock() - t0
        while i < len(pending) and pending[i][0] <= now:
            engine.submit_request(pending[i][1])
            i += 1
        if engine.has_work:
            engine.step()
        elif i < len(pending):
            time.sleep(min(1e-3, max(0.0, pending[i][0] - (clock() - t0))))
    wall = clock() - t0
    done = engine.finished
    lat = np.asarray([r.latency for r in done])
    ttft = np.asarray([r.ttft for r in done])
    n_tokens = int(sum(len(r.tokens) for r in done))
    return {
        "n_requests": float(len(done)),
        "n_tokens": float(n_tokens),
        "wall_s": wall,
        "tok_per_s": n_tokens / wall if wall > 0 else float("inf"),
        "p50_latency_s": float(np.percentile(lat, 50)) if len(lat) else math.nan,
        "p99_latency_s": float(np.percentile(lat, 99)) if len(lat) else math.nan,
        "p50_ttft_s": float(np.percentile(ttft, 50)) if len(ttft) else math.nan,
        "p99_ttft_s": float(np.percentile(ttft, 99)) if len(ttft) else math.nan,
    }
