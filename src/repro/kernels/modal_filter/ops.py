"""Public wrapper: modal filter materialization.

On TPU this dispatches to the Pallas kernel (interpret=False); on CPU the
kernel runs in interpret mode for correctness tests, while production CPU
paths use the jnp reference (same math).
"""
from __future__ import annotations

import jax

from repro.kernels.modal_filter.modal_filter import modal_filter_pallas
from repro.kernels.modal_filter.ref import modal_filter_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def modal_filter(log_a, theta, R_re, R_im, h0, L: int, *,
                 use_pallas: bool = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return modal_filter_pallas(log_a, theta, R_re, R_im, h0, L=L,
                                   interpret=not _on_tpu())
    return modal_filter_ref(log_a, theta, R_re, R_im, h0, L)
