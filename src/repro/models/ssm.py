"""SSM blocks: Mamba-2 (SSD, arXiv:2405.21060) and RG-LRU (RecurrentGemma).

Both provide a full-sequence mode (chunked-matmul SSD / associative scan) and
an O(1)-state decode step, which is what makes the long_500k cell lowerable.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Param
from repro.models.layers import (
    NOCTX, ShardCtx, apply_short_conv, conv_tail_gather, dense_init,
    init_short_conv, short_conv_chunk, short_conv_step,
)


# ===========================================================================
# Mamba-2 (SSD)
# ===========================================================================
def init_mamba2_block(key, cfg):
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    H = di // s.head_dim
    G = s.n_groups
    conv_dim = di + 2 * G * s.d_state
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        # fused input projection: [z(di), x(di), B(G*N), C(G*N), dt(H)]
        "in_proj": dense_init(k1, (d, 2 * di + 2 * G * s.d_state + H),
                              ("embed", "mlp"), in_dim=d),
        "conv": init_short_conv(k2, conv_dim, s.d_conv),
        "A_log": Param(jnp.log(jnp.linspace(1.0, 16.0, H)), ("heads",)),
        "D": Param(jnp.ones((H,)), ("heads",)),
        "dt_bias": Param(jnp.log(jnp.exp(jnp.linspace(1e-3, 0.1, H)) - 1.0 + 1e-9),
                         ("heads",)),
        "norm_scale": Param(jnp.ones((di,)), ("mlp",)),
        "out_proj": dense_init(k3, (di, d), ("mlp", "embed"), in_dim=di),
    }


def _split_mamba_proj(proj, cfg):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = di // s.head_dim
    G, N = s.n_groups, s.d_state
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * G * N], axis=-1)
    return z, xBC, dt, di, H, G, N


def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = np.tril(np.ones((Q, Q), dtype=bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, a_log, B, C, chunk: int, initial_state=None):
    """Chunked SSD (Mamba-2 Listing 1, JAX port).

    x: (b, L, H, P) pre-scaled by dt; a_log: (b, L, H) = dt*A (negative);
    B, C: (b, L, G, N). Returns y (b, L, H, P) and final state (b, H, P, N).
    `initial_state` (b, H, P, N) resumes from a previous segment (chunked
    prefill); omitted, the recurrence starts from zero as before.
    """
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Q = min(chunk, L)
    nc = L // Q
    assert L % Q == 0, (L, Q)
    xr = x.reshape(b, nc, Q, H, P)
    ar = a_log.reshape(b, nc, Q, H).transpose(0, 3, 1, 2)       # (b,H,nc,Q)
    Br = B.reshape(b, nc, Q, G, N)
    Cr = C.reshape(b, nc, Q, G, N)
    rep = H // G
    Brh = jnp.repeat(Br, rep, axis=3)                            # (b,nc,Q,H,N)
    Crh = jnp.repeat(Cr, rep, axis=3)

    # 1. intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(ar))                                  # (b,H,nc,Q,Q)
    Ydiag = jnp.einsum("bcqhn,bckhn,bhcqk,bckhp->bcqhp", Crh, Brh, Lmat, xr)

    # 2. chunk states
    a_cum = jnp.cumsum(ar, axis=-1)                              # (b,H,nc,Q)
    a_tot = a_cum[..., -1]                                       # (b,H,nc)
    decay_to_end = jnp.exp(a_tot[..., None] - a_cum)             # (b,H,nc,Q)
    states = jnp.einsum("bckhn,bhck,bckhp->bchpn", Brh, decay_to_end, xr)

    # 3. inter-chunk recurrence on states (scan over chunks)
    def scan_fn(carry, inp):
        st, atot = inp                                           # (b,H,P,N), (b,H)
        new = carry * jnp.exp(atot)[..., None, None] + st
        return new, carry                                        # emit state BEFORE chunk

    from repro import flags
    init = (jnp.zeros((b, H, P, N), x.dtype) if initial_state is None
            else initial_state.astype(x.dtype))
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), a_tot.transpose(2, 0, 1)),
        unroll=flags.scan_unroll(nc))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # (b,nc,H,P,N)

    # 4. off-diagonal contribution
    decay_in = jnp.exp(a_cum)                                    # (b,H,nc,Q)
    Yoff = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", Crh, prev_states, decay_in)
    y = (Ydiag + Yoff).reshape(b, L, H, P)
    return y, final


def mamba2_block(params, x, cfg, *, ctx: ShardCtx = NOCTX, return_state=False,
                 lengths=None):
    """Full-sequence Mamba-2 block. x: (B, S, D).

    `lengths` (B,) marks true prompt lengths for bucketed prefill: padded
    positions get dt = 0, i.e. an identity transition (decay 1, input 0), so
    the final state is exactly the state at each row's true length.
    """
    Bsz, S, D = x.shape
    s = cfg.ssm
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xBC, dt, di, H, G, N = _split_mamba_proj(proj, cfg)
    pre_conv = xBC
    xBC = jax.nn.silu(apply_short_conv(params["conv"], xBC))
    xs, B_, C_ = jnp.split(xBC, [di, di + G * N], axis=-1)
    B_ = B_.reshape(Bsz, S, G, N)
    C_ = C_.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    if lengths is not None:
        dt = jnp.where(jnp.arange(S)[None, :, None] < lengths[:, None, None],
                       dt, 0.0)
    A = -jnp.exp(params["A_log"])                                      # (H,)
    xh = xs.reshape(Bsz, S, H, s.head_dim).astype(jnp.float32)
    y, state = ssd_chunked(xh * dt[..., None], dt * A, B_.astype(jnp.float32),
                           C_.astype(jnp.float32), s.chunk)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    # gated RMSNorm (Mamba-2)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) *
         params["norm_scale"]).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    if return_state:
        w = s.d_conv - 1
        cache = {"conv": conv_tail_gather(pre_conv, w, lengths).astype(jnp.float32),
                 "ssm": state.astype(jnp.float32)}
        return out, cache
    return out


def init_mamba2_cache(batch: int, cfg, dtype=jnp.float32) -> Dict:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = di // s.head_dim
    conv_dim = di + 2 * s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), dtype),
    }


def mamba2_decode(params, cache, x, cfg, *, ctx: ShardCtx = NOCTX):
    """One-token decode. x: (B, 1, D); O(1) state."""
    Bsz, _, D = x.shape
    s = cfg.ssm
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))[:, 0]
    z, xBC, dt, di, H, G, N = _split_mamba_proj(proj, cfg)
    conv_cache, xBC = short_conv_step(params["conv"], cache["conv"], xBC)
    xBC = jax.nn.silu(xBC)
    xs, B_, C_ = jnp.split(xBC, [di, di + G * N], axis=-1)
    B_ = B_.reshape(Bsz, G, N).astype(jnp.float32)
    C_ = C_.reshape(Bsz, G, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)                                                # (B,H)
    xh = xs.reshape(Bsz, H, s.head_dim).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=1)                                   # (B,H,N)
    Ch = jnp.repeat(C_, rep, axis=1)
    h = cache["ssm"] * a[..., None, None] + \
        jnp.einsum("bhn,bhp->bhpn", Bh, xh * dt[..., None])
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch) + params["D"][None, :, None] * xh
    y = y.reshape(Bsz, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) *
         params["norm_scale"]).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"].astype(x.dtype))
    return {"conv": conv_cache, "ssm": h}, out[:, None, :]


def mamba2_decode_chunk(params, cache, x, active_len, cfg, *,
                        ctx: ShardCtx = NOCTX):
    """Multi-token decode on the decode cache (speculative verify / replay).
    x: (B, C, D); active_len (B,) — positions past a row's active_len get
    dt = 0 (identity transition, zero input) so its conv tail and SSM state
    advance by exactly active_len tokens. Runs the chunk path through
    `ssd_chunked(initial_state=cache["ssm"])`."""
    from repro.models.hyena import _short_conv_rows
    Bsz, C, D = x.shape
    s = cfg.ssm
    active_len = jnp.asarray(active_len, jnp.int32)
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xBC, dt, di, H, G, N = _split_mamba_proj(proj, cfg)
    new_tail, xBC, _ = _short_conv_rows(params["conv"], cache["conv"], xBC,
                                        active_len)
    xBC = jax.nn.silu(xBC)
    xs, B_, C_ = jnp.split(xBC, [di, di + G * N], axis=-1)
    B_ = B_.reshape(Bsz, C, G, N)
    C_ = C_.reshape(Bsz, C, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    dt = jnp.where(jnp.arange(C)[None, :, None] < active_len[:, None, None],
                   dt, 0.0)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(Bsz, C, H, s.head_dim).astype(jnp.float32)
    y, state = ssd_chunked(xh * dt[..., None], dt * A, B_.astype(jnp.float32),
                           C_.astype(jnp.float32), C,
                           initial_state=cache["ssm"])
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(Bsz, C, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) *
         params["norm_scale"]).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    return {"conv": new_tail.astype(jnp.float32),
            "ssm": state.astype(jnp.float32)}, out


def rglru_decode_chunk(params, cache, x, active_len, cfg, *,
                       ctx: ShardCtx = NOCTX):
    """Multi-token RG-LRU decode on the decode cache. Positions past a row's
    active_len become identity transitions (a = 1, input 0), so h[:, -1] is
    the state after exactly active_len tokens."""
    from repro.models.hyena import _short_conv_rows
    C = x.shape[1]
    active_len = jnp.asarray(active_len, jnp.int32)
    xb = jnp.einsum("bsd,de->bse", x, params["wx"].astype(x.dtype))
    yb = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["wy"].astype(x.dtype)))
    new_tail, xc, _ = _short_conv_rows(params["conv"], cache["conv"], xb,
                                       active_len)
    log_a, gated = _rglru_gates(params, xc)
    valid = (jnp.arange(C)[None, :, None] < active_len[:, None, None])
    log_a = jnp.where(valid, log_a, 0.0)
    gated = jnp.where(valid, gated, 0.0)
    a = jnp.exp(log_a)
    a_cum, h = jax.lax.associative_scan(_rglru_combine, (a, gated), axis=1)
    h = h + a_cum * cache["h"][:, None, :]
    out = h.astype(x.dtype) * yb
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return {"conv": new_tail.astype(jnp.float32),
            "h": h[:, -1, :].astype(jnp.float32)}, out


def mamba2_prefill_chunk(params, cache, x, chunk_len, cfg, *,
                         ctx: ShardCtx = NOCTX):
    """Consume one prompt chunk x (B, C, D) resuming from cache{conv, ssm}.
    Positions >= chunk_len are padding (identity transitions)."""
    Bsz, C, D = x.shape
    s = cfg.ssm
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xBC, dt, di, H, G, N = _split_mamba_proj(proj, cfg)
    new_tail, xBC = short_conv_chunk(params["conv"], cache["conv"], xBC,
                                     chunk_len)
    xBC = jax.nn.silu(xBC)
    xs, B_, C_ = jnp.split(xBC, [di, di + G * N], axis=-1)
    B_ = B_.reshape(Bsz, C, G, N)
    C_ = C_.reshape(Bsz, C, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    dt = jnp.where(jnp.arange(C)[None, :, None] < chunk_len, dt, 0.0)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(Bsz, C, H, s.head_dim).astype(jnp.float32)
    y, state = ssd_chunked(xh * dt[..., None], dt * A, B_.astype(jnp.float32),
                           C_.astype(jnp.float32), s.chunk,
                           initial_state=cache["ssm"])
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(Bsz, C, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) *
         params["norm_scale"]).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    return {"conv": new_tail.astype(jnp.float32),
            "ssm": state.astype(jnp.float32)}, out


# ===========================================================================
# RG-LRU (RecurrentGemma / Griffin)
# ===========================================================================
_RG_C = 8.0


def init_rglru_block(key, cfg):
    d = cfg.d_model
    r = cfg.rglru
    di = r.expand * d
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "wx": dense_init(k1, (d, di), ("embed", "mlp"), in_dim=d),
        "wy": dense_init(k2, (d, di), ("embed", "mlp"), in_dim=d),
        "conv": init_short_conv(k3, di, r.d_conv),
        "wa": dense_init(k4, (di, di), ("mlp", "mlp"), in_dim=di),
        "wi": dense_init(k5, (di, di), ("mlp", "mlp"), in_dim=di),
        # Lambda init so that a = sigmoid(lam)^c is in [0.9, 0.999]
        "lam": Param(jnp.linspace(2.0, 6.0, di), ("mlp",)),
        "wo": dense_init(k6, (di, d), ("mlp", "embed"), in_dim=di),
    }


def _rglru_gates(params, xc):
    """Returns (log_a, gated_input): log_a (B,S,di) <= 0."""
    r_gate = jax.nn.sigmoid(jnp.einsum("...e,ef->...f", xc, params["wa"].astype(xc.dtype)))
    i_gate = jax.nn.sigmoid(jnp.einsum("...e,ef->...f", xc, params["wi"].astype(xc.dtype)))
    log_a = -_RG_C * r_gate.astype(jnp.float32) * jax.nn.softplus(params["lam"])
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * (i_gate * xc).astype(jnp.float32)
    return log_a, gated


def _rglru_combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def rglru_block(params, x, cfg, *, ctx: ShardCtx = NOCTX, return_state=False,
                lengths=None):
    """Full-sequence RG-LRU block via associative scan. x: (B,S,D).

    With `lengths` (B,), padded positions become identity transitions
    (a = 1, input 0) so the final state is the state at the true length.
    """
    xb = jnp.einsum("bsd,de->bse", x, params["wx"].astype(x.dtype))
    yb = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["wy"].astype(x.dtype)))
    xc = apply_short_conv(params["conv"], xb)
    log_a, gated = _rglru_gates(params, xc)
    if lengths is not None:
        valid = (jnp.arange(x.shape[1])[None, :, None] <
                 lengths[:, None, None])
        log_a = jnp.where(valid, log_a, 0.0)
        gated = jnp.where(valid, gated, 0.0)
    a = jnp.exp(log_a)

    _, h = jax.lax.associative_scan(_rglru_combine, (a, gated), axis=1)
    out = h.astype(x.dtype) * yb
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    if return_state:
        w = cfg.rglru.d_conv - 1
        cache = {"conv": conv_tail_gather(xb, w, lengths).astype(jnp.float32),
                 "h": h[:, -1, :].astype(jnp.float32)}
        return out, cache
    return out


def init_rglru_cache(batch: int, cfg, dtype=jnp.float32) -> Dict:
    r = cfg.rglru
    di = r.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, r.d_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di), dtype),
    }


def rglru_decode(params, cache, x, cfg, *, ctx: ShardCtx = NOCTX):
    xb = jnp.einsum("bsd,de->bse", x, params["wx"].astype(x.dtype))[:, 0]
    yb = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["wy"].astype(x.dtype)))[:, 0]
    conv_cache, xc = short_conv_step(params["conv"], cache["conv"], xb)
    log_a, gated = _rglru_gates(params, xc)
    h = jnp.exp(log_a) * cache["h"] + gated
    out = h.astype(x.dtype) * yb
    out = jnp.einsum("be,ed->bd", out, params["wo"].astype(x.dtype))
    return {"conv": conv_cache, "h": h}, out[:, None, :]


def rglru_prefill_chunk(params, cache, x, chunk_len, cfg, *,
                        ctx: ShardCtx = NOCTX):
    """Consume one prompt chunk x (B, C, D) resuming from cache{conv, h}.
    Positions >= chunk_len are padding (identity transitions)."""
    C = x.shape[1]
    xb = jnp.einsum("bsd,de->bse", x, params["wx"].astype(x.dtype))
    yb = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["wy"].astype(x.dtype)))
    new_tail, xc = short_conv_chunk(params["conv"], cache["conv"], xb,
                                    chunk_len)
    log_a, gated = _rglru_gates(params, xc)
    valid = (jnp.arange(C) < chunk_len)[None, :, None]
    log_a = jnp.where(valid, log_a, 0.0)
    gated = jnp.where(valid, gated, 0.0)
    a = jnp.exp(log_a)
    a_cum, h = jax.lax.associative_scan(_rglru_combine, (a, gated), axis=1)
    h = h + a_cum * cache["h"][:, None, :]          # fold in the carried state
    out = h.astype(x.dtype) * yb
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return {"conv": new_tail.astype(jnp.float32),
            "h": h[:, -1, :].astype(jnp.float32)}, out
