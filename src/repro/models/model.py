"""Top-level model: init / forward / train_loss / prefill / decode_step.

Every architecture in the pool is an instance of this assembly:
  embed -> [scan over pattern groups of blocks] -> remainder blocks -> norm -> logits
with optional encoder (whisper) and modality-frontend stubs (qwen2-vl audio).

Layer stacking: parameters of one pattern period ("group") are initialized per
group and stacked on a leading axis, then consumed by jax.lax.scan — keeping
HLO size O(pattern) instead of O(n_layers), which matters when compiling
80-layer models for 512 devices.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, HYENA, LOCAL_ATTN, MAMBA2, MLP_MOE,
                                RGLRU, ModelConfig)
from repro.distributed.sharding import Param
from repro.models import attention as attn_mod
from repro.models import hyena as hyena_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (NOCTX, ShardCtx, apply_mlp, apply_norm,
                                 embed_tokens, init_embed, init_mlp, init_norm,
                                 unembed)

is_param = lambda x: isinstance(x, Param)


def stack_groups(groups):
    """Stack a list of Param trees along a new leading (layer) axis."""
    def stack(*ps):
        return Param(jnp.stack([p.value for p in ps]), (None,) + tuple(ps[0].axes))
    return jax.tree.map(stack, *groups, is_leaf=is_param)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(key, kind: str, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": init_norm(cfg.norm, cfg.d_model)}
    if kind in (ATTN, LOCAL_ATTN):
        p["mix"] = attn_mod.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.hd)
    elif kind == HYENA:
        p["mix"] = hyena_mod.init_hyena_block(ks[0], cfg)
    elif kind == MAMBA2:
        p["mix"] = ssm_mod.init_mamba2_block(ks[0], cfg)
    elif kind == RGLRU:
        p["mix"] = ssm_mod.init_rglru_block(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["cross_norm"] = init_norm(cfg.norm, cfg.d_model)
        p["cross"] = attn_mod.init_attention(ks[1], cfg.d_model, cfg.n_heads,
                                             cfg.n_kv_heads, cfg.hd)
    if cfg.d_ff > 0:
        p["norm2"] = init_norm(cfg.norm, cfg.d_model)
        if cfg.mlp_kind == MLP_MOE:
            p["mlp"] = moe_mod.init_moe(ks[2], cfg.d_model, cfg.d_ff, cfg.moe)
        else:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act)
    return p


def _init_group(key, cfg: ModelConfig, cross: bool):
    ks = jax.random.split(key, len(cfg.pattern))
    return {f"l{i}": _init_block(ks[i], kind, cfg, cross)
            for i, kind in enumerate(cfg.pattern)}


def layer_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups scanned, n_remainder unstacked layers)."""
    period = len(cfg.pattern)
    return cfg.n_layers // period, cfg.n_layers % period


def init_params(key, cfg: ModelConfig):
    """Returns a Param tree (values + logical axes)."""
    n_groups, n_rem = layer_layout(cfg)
    keys = jax.random.split(key, n_groups + n_rem + 4)
    cross = cfg.enc_dec
    groups = [_init_group(keys[i], cfg, cross) for i in range(n_groups)]
    params: Dict[str, Any] = {
        "embed": init_embed(keys[-1], cfg.vocab, cfg.d_model, cfg.tie_embeddings,
                            max_seq=max(cfg.max_seq, 1),
                            learned_pos=(cfg.rope_theta <= 0.0)),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
        "groups": stack_groups(groups),
    }
    if n_rem:
        params["rem"] = [
            _init_block(keys[n_groups + i], cfg.blocks[n_groups * len(cfg.pattern) + i],
                        cfg, cross)
            for i in range(n_rem)
        ]
    if cfg.enc_dec:
        ekeys = jax.random.split(keys[-2], cfg.n_enc_layers)
        enc = [_init_block(ekeys[i], ATTN, cfg, cross=False)
               for i in range(cfg.n_enc_layers)]
        params["encoder"] = stack_groups(enc)
        params["enc_norm"] = init_norm(cfg.norm, cfg.d_model)
        params["enc_pos"] = Param(
            jax.random.normal(keys[-3], (cfg.frontend_len, cfg.d_model)) * 0.02,
            (None, "embed"))
    return params


# ---------------------------------------------------------------------------
# Forward (full sequence)
# ---------------------------------------------------------------------------
def _apply_block(bp, kind: str, x, positions, cfg: ModelConfig, ctx: ShardCtx,
                 *, enc_out=None, moe_impl: str, collect_cache: bool = False,
                 cross_kv_cache=None, cache_kind: str = "native",
                 lengths=None, filter_len=None):
    """One block (mix + mlp). Returns (x, aux_loss, cache_or_None).

    `lengths` (B,) marks true per-row prompt lengths for bucketed (right-
    padded) prefill; it only affects what the collected caches contain —
    padded positions never reach the SSM states, conv tails, or KV caches.
    `filter_len` pins the Hyena filter materialization length (serving).
    """
    h = apply_norm(bp["norm1"], x, cfg.norm)
    cache = None
    window = cfg.window if kind == LOCAL_ATTN else 0
    kv_valid = (None if lengths is None else
                jnp.arange(x.shape[1])[None, :] < lengths[:, None])
    if kind in (ATTN, LOCAL_ATTN):
        if collect_cache:
            y, (k, v) = attn_mod.attention_block(
                bp["mix"], h, positions, cfg, window=window, ctx=ctx,
                return_kv=True, kv_valid=kv_valid)
            cache = {"k": k, "v": v}
        else:
            y = attn_mod.attention_block(bp["mix"], h, positions, cfg,
                                         window=window, ctx=ctx)
    elif kind == HYENA:
        if collect_cache:
            y, cache = hyena_mod.hyena_block(bp["mix"], h, cfg, ctx=ctx,
                                             return_cache=True,
                                             cache_kind=cache_kind,
                                             lengths=lengths,
                                             filter_len=filter_len)
        else:
            y = hyena_mod.hyena_block(bp["mix"], h, cfg, ctx=ctx,
                                      filter_len=filter_len)
    elif kind == MAMBA2:
        if collect_cache:
            y, cache = ssm_mod.mamba2_block(bp["mix"], h, cfg, ctx=ctx,
                                            return_state=True, lengths=lengths)
        else:
            y = ssm_mod.mamba2_block(bp["mix"], h, cfg, ctx=ctx)
    elif kind == RGLRU:
        if collect_cache:
            y, cache = ssm_mod.rglru_block(bp["mix"], h, cfg, ctx=ctx,
                                           return_state=True, lengths=lengths)
        else:
            y = ssm_mod.rglru_block(bp["mix"], h, cfg, ctx=ctx)
    else:
        raise ValueError(kind)
    x = ctx.cs(x + y, ("batch", None, "act_embed"))
    if "cross" in bp:
        h = apply_norm(bp["cross_norm"], x, cfg.norm)
        if cross_kv_cache is not None:
            kv = cross_kv_cache
        else:
            assert enc_out is not None
            kv = attn_mod.compute_kv(bp["cross"], enc_out, None, cfg)
        y = attn_mod.attention_block(bp["cross"], h, positions, cfg, ctx=ctx,
                                     cross_kv=kv)
        x = x + y
        if collect_cache and cache is not None:
            cache["cross_k"], cache["cross_v"] = kv
        elif collect_cache:
            cache = {"cross_k": kv[0], "cross_v": kv[1]}
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        h = apply_norm(bp["norm2"], x, cfg.norm)
        if cfg.mlp_kind == MLP_MOE:
            y, aux = moe_mod.moe_block(bp["mlp"], h, cfg.moe, impl=moe_impl, ctx=ctx)
        else:
            y = apply_mlp(bp["mlp"], h, cfg.act, ctx=ctx)
        x = ctx.cs(x + y, ("batch", None, "act_embed"))
    return x, aux, cache


def forward(params, tokens, cfg: ModelConfig, *, ctx: ShardCtx = NOCTX,
            frontend: Optional[jnp.ndarray] = None, moe_impl: str = "dropless",
            remat: Optional[str] = "none", collect_cache: bool = False,
            cache_kind: str = "native", lengths=None,
            filter_len: Optional[int] = None):
    """Full-sequence forward. tokens: (B, S) int32.

    Returns logits (B, S', vocab) and, with collect_cache, the per-layer
    decode caches (for prefill). For VLM, `frontend` embeddings are prepended
    (S' includes them). For enc-dec, `frontend` feeds the encoder.
    cache_kind: "native" (recurrent/kv states) or "conv" (Hyena layers cache
    the k.v product sequence for the Lemma-2.1 cached-conv baseline).
    `lengths` (B,) supports bucketed prefill: rows are right-padded to S and
    collected caches are masked to each row's true length.
    """
    if lengths is not None and frontend is not None:
        raise ValueError("lengths (bucketed prefill) is incompatible with "
                         "frontend inputs")
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = embed_tokens(params["embed"], tokens, ctx=ctx, dtype=dtype)
    enc_out = None
    if cfg.enc_dec and frontend is not None:
        enc_out = encode_stack(params, frontend.astype(dtype), cfg, ctx)
    elif frontend is not None:                       # VLM: prepend patch embeds
        x = jnp.concatenate([frontend.astype(dtype), x], axis=1)
    if cfg.rope_theta <= 0.0:                        # learned absolute positions
        x = x + params["embed"]["pos"][None, :x.shape[1], :].astype(dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :],
                                 (x.shape[0], x.shape[1]))

    n_groups, n_rem = layer_layout(cfg)

    def group_body(carry, gp):
        x, aux = carry
        caches = {}
        for i, kind in enumerate(cfg.pattern):
            x, a, c = _apply_block(gp[f"l{i}"], kind, x, positions, cfg, ctx,
                                   enc_out=enc_out, moe_impl=moe_impl,
                                   collect_cache=collect_cache,
                                   cache_kind=cache_kind, lengths=lengths,
                                   filter_len=filter_len)
            aux = aux + a
            if collect_cache:
                caches[f"l{i}"] = c
        return (x, aux), (caches if collect_cache else None)

    body = group_body
    if remat and remat != "none":
        policy = {
            "full": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.checkpoint_dots,
            "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        }[remat]
        body = jax.checkpoint(group_body, policy=policy)

    from repro import flags
    n_g = jax.tree.leaves(params["groups"])[0].shape[0]
    (x, aux), scan_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                         params["groups"],
                                         unroll=flags.scan_unroll(n_g))
    rem_caches = []
    for i in range(n_rem):
        kind = cfg.blocks[n_groups * len(cfg.pattern) + i]
        x, a, c = _apply_block(params["rem"][i], kind, x, positions, cfg, ctx,
                               enc_out=enc_out, moe_impl=moe_impl,
                               collect_cache=collect_cache,
                               cache_kind=cache_kind, lengths=lengths,
                               filter_len=filter_len)
        aux = aux + a
        rem_caches.append(c)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     softcap=cfg.logit_softcap, ctx=ctx)
    if collect_cache:
        return logits, aux, (scan_caches, rem_caches)
    return logits, aux


def encode_stack(params, frontend_emb, cfg: ModelConfig, ctx: ShardCtx):
    x = frontend_emb + params["enc_pos"][None, :frontend_emb.shape[1], :].astype(
        frontend_emb.dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :],
                                 (x.shape[0], x.shape[1]))

    def body(carry, lp):
        h = apply_norm(lp["norm1"], carry, cfg.norm)
        y = attn_mod.attention_block(lp["mix"], h, positions, cfg, ctx=ctx,
                                     causal=False)
        carry = carry + y
        h = apply_norm(lp["norm2"], carry, cfg.norm)
        carry = carry + apply_mlp(lp["mlp"], h, cfg.act, ctx=ctx)
        return carry, None

    from repro import flags
    n_e = jax.tree.leaves(params["encoder"])[0].shape[0]
    x, _ = jax.lax.scan(body, x, params["encoder"], unroll=flags.scan_unroll(n_e))
    return apply_norm(params["enc_norm"], x, cfg.norm)


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------
def train_loss(params, batch, cfg: ModelConfig, *, ctx: ShardCtx = NOCTX,
               moe_impl: str = "dropless", remat: str = "none"):
    """batch: {tokens (B,S), [frontend]}. Next-token cross-entropy."""
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens[:, :-1], cfg, ctx=ctx,
                          frontend=batch.get("frontend"), moe_impl=moe_impl,
                          remat=remat)
    targets = tokens[:, 1:]
    if logits.shape[1] != targets.shape[1]:          # VLM prepended frontend
        logits = logits[:, -targets.shape[1]:, :]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def _init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                      cross: bool, cache_kind: str = "native"):
    c: Dict[str, Any] = {}
    if kind in (ATTN, LOCAL_ATTN):
        eff = max_len if kind == ATTN or cfg.window <= 0 else min(max_len, cfg.window)
        kv = attn_mod.init_kv_cache(batch, eff, cfg.n_kv_heads, cfg.hd)
        c["k"] = Param(kv["k"], ("batch", "kv_seq", "kv_heads", None))
        c["v"] = Param(kv["v"], ("batch", "kv_seq", "kv_heads", None))
        if eff < max_len:                       # ring buffer for windowed layers
            c["slot_pos"] = Param(jnp.full((batch, eff), -1, jnp.int32),
                                  ("batch", None))
    elif kind == HYENA and cache_kind == "conv":
        hc = hyena_mod.init_hyena_conv_cache(batch, max_len, cfg)
        c["conv"] = Param(hc["conv"], ("batch", None, "qkv"))
        c["kv"] = Param(hc["kv"], ("batch", "kv_seq", "qkv"))
    elif kind == HYENA and cache_kind == "epoch":
        hc = hyena_mod.init_hyena_epoch_cache(batch, max_len, cfg)
        c["conv"] = Param(hc["conv"], ("batch", None, "qkv"))
        c["kv"] = Param(hc["kv"], ("batch", "kv_seq", "qkv"))
        c["fut"] = Param(hc["fut"], ("batch", "kv_seq", "qkv"))
        c["epoch"] = Param(hc["epoch"], ("batch",))
    elif kind == HYENA:
        hc = hyena_mod.init_hyena_cache(batch, cfg)
        c["conv"] = Param(hc["conv"], ("batch", None, "qkv"))
        c["x_re"] = Param(hc["x_re"], ("batch", "qkv", "state"))
        c["x_im"] = Param(hc["x_im"], ("batch", "qkv", "state"))
    elif kind == MAMBA2:
        mc = ssm_mod.init_mamba2_cache(batch, cfg)
        c["conv"] = Param(mc["conv"], ("batch", None, "mlp"))
        c["ssm"] = Param(mc["ssm"], ("batch", "heads", None, "state"))
    elif kind == RGLRU:
        rc = ssm_mod.init_rglru_cache(batch, cfg)
        c["conv"] = Param(rc["conv"], ("batch", None, "mlp"))
        c["h"] = Param(rc["h"], ("batch", "mlp"))
    if cross:
        F = cfg.frontend_len
        c["cross_k"] = Param(jnp.zeros((batch, F, cfg.n_kv_heads, cfg.hd),
                                       jnp.bfloat16),
                             ("batch", "kv_seq", "kv_heads", None))
        c["cross_v"] = Param(jnp.zeros((batch, F, cfg.n_kv_heads, cfg.hd),
                                       jnp.bfloat16),
                             ("batch", "kv_seq", "kv_heads", None))
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               cache_kind: str = "native", per_slot: bool = False):
    """Param-tree of decode caches (leading group axis on scanned layers).

    per_slot=True gives each batch row its own position counter (B,) — the
    layout the continuous-batching engine uses, where every slot holds an
    independent request at its own decode position.
    """
    n_groups, n_rem = layer_layout(cfg)
    group = {f"l{i}": _init_block_cache(kind, cfg, batch, max_len, cfg.enc_dec,
                                        cache_kind)
             for i, kind in enumerate(cfg.pattern)}
    stacked = jax.tree.map(
        lambda p: Param(jnp.broadcast_to(p.value, (n_groups,) + p.value.shape),
                        (None,) + tuple(p.axes)),
        group, is_leaf=is_param)
    pos = (Param(jnp.zeros((batch,), jnp.int32), ("batch",)) if per_slot
           else Param(jnp.zeros((), jnp.int32), ()))
    cache: Dict[str, Any] = {"groups": stacked, "pos": pos}
    if n_rem:
        cache["rem"] = [
            _init_block_cache(cfg.blocks[n_groups * len(cfg.pattern) + i], cfg,
                              batch, max_len, cfg.enc_dec, cache_kind)
            for i in range(n_rem)
        ]
    return cache


def _decode_block(bp, bc, kind: str, x, pos, cfg: ModelConfig, ctx: ShardCtx,
                  conv_filters=None):
    h = apply_norm(bp["norm1"], x, cfg.norm)
    window = cfg.window if kind == LOCAL_ATTN else 0
    if kind in (ATTN, LOCAL_ATTN):
        kv = {k: bc[k] for k in ("k", "v", "slot_pos") if k in bc}
        kv, y = attn_mod.attention_decode(bp["mix"], kv, h, pos, cfg,
                                          window=window, ctx=ctx)
        bc = dict(bc, **kv)
    elif kind == HYENA:
        if "fut" in bc:           # FutureFill epoched exact decode
            sub = {k: bc[k] for k in ("conv", "kv", "fut", "epoch")}
            if conv_filters is None:   # fallback: re-materialize every step
                conv_filters = hyena_mod.materialize_filters(
                    bp["mix"]["filter"], bc["kv"].shape[1], cfg.hyena)
            sub, y = hyena_mod.hyena_decode_epoch(
                bp["mix"], sub, h, pos, cfg, conv_filters, ctx=ctx)
        elif "kv" in bc:          # Lemma-2.1 cached-conv baseline (O(t)/token)
            sub = {k: bc[k] for k in ("conv", "kv")}
            if conv_filters is None:   # fallback: re-materialize every step
                conv_filters = hyena_mod.materialize_filters(
                    bp["mix"]["filter"], bc["kv"].shape[1], cfg.hyena)
            sub, y = hyena_mod.hyena_decode_cached_conv(
                bp["mix"], sub, h, pos, cfg, conv_filters, ctx=ctx)
        else:                     # distilled modal recurrence (O(d)/token)
            sub = {k: bc[k] for k in ("conv", "x_re", "x_im")}
            sub, y = hyena_mod.hyena_decode(bp["mix"], sub, h, cfg, ctx=ctx)
        bc = dict(bc, **sub)
    elif kind == MAMBA2:
        sub = {k: bc[k] for k in ("conv", "ssm")}
        sub, y = ssm_mod.mamba2_decode(bp["mix"], sub, h, cfg, ctx=ctx)
        bc = dict(bc, **sub)
    elif kind == RGLRU:
        sub = {k: bc[k] for k in ("conv", "h")}
        sub, y = ssm_mod.rglru_decode(bp["mix"], sub, h, cfg, ctx=ctx)
        bc = dict(bc, **sub)
    else:
        raise ValueError(kind)
    x = x + y
    if "cross" in bp:
        h = apply_norm(bp["cross_norm"], x, cfg.norm)
        y = attn_mod.attention_block(bp["cross"], h,
                                     jnp.zeros((x.shape[0], 1), jnp.int32), cfg,
                                     ctx=ctx, cross_kv=(bc["cross_k"], bc["cross_v"]))
        x = x + y
    if cfg.d_ff > 0:
        h = apply_norm(bp["norm2"], x, cfg.norm)
        if cfg.mlp_kind == MLP_MOE:
            y, _ = moe_mod.moe_block(bp["mlp"], h, cfg.moe, ctx=ctx)
        else:
            y = apply_mlp(bp["mlp"], h, cfg.act, ctx=ctx)
        x = x + y
    return bc, x


def decode_step(params, cache, tokens, cfg: ModelConfig, *, ctx: ShardCtx = NOCTX,
                conv_filters=None):
    """One decode step. tokens: (B, 1) int32. Returns (cache, logits).

    cache["pos"] is either a scalar (uniform batch: every row at the same
    position) or a (B,) vector (continuous batching: one position per slot).
    conv_filters (from `materialize_conv_filters`) supplies pre-materialized
    long filters for cached-conv Hyena layers; without it each decode step
    re-runs the filter MLP (hot-loop waste — engines always pass it).
    """
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    pos = jnp.asarray(cache["pos"], jnp.int32)
    x = embed_tokens(params["embed"], tokens, ctx=ctx, dtype=dtype)
    if cfg.rope_theta <= 0.0:
        pe = params["embed"]["pos"]
        if pos.ndim == 1:
            x = x + jnp.take(pe, jnp.clip(pos, 0, pe.shape[0] - 1),
                             axis=0)[:, None, :].astype(dtype)
        else:
            x = x + jax.lax.dynamic_slice_in_dim(
                pe, pos, 1, axis=0)[None].astype(dtype)[:, 0:1]
    n_groups, n_rem = layer_layout(cfg)

    def body(x, gp_gc):
        gp, gc = gp_gc[0], gp_gc[1]
        gf = gp_gc[2] if len(gp_gc) > 2 else {}
        for i, kind in enumerate(cfg.pattern):
            gc[f"l{i}"], x = _decode_block(gp[f"l{i}"], gc[f"l{i}"], kind, x,
                                           pos, cfg, ctx,
                                           conv_filters=gf.get(f"l{i}"))
        return x, gc

    from repro import flags
    n_g = jax.tree.leaves(params["groups"])[0].shape[0]
    xs = (params["groups"], cache["groups"])
    if conv_filters is not None:
        xs = xs + (conv_filters["groups"],)
    x, new_group_caches = jax.lax.scan(body, x, xs,
                                       unroll=flags.decode_unroll(n_g))
    new_cache = {"groups": new_group_caches, "pos": pos + 1}
    if n_rem:
        rem_filters = (conv_filters or {}).get("rem", {})
        rem = []
        for i in range(n_rem):
            kind = cfg.blocks[n_groups * len(cfg.pattern) + i]
            bc, x = _decode_block(params["rem"][i], cache["rem"][i], kind, x,
                                  pos, cfg, ctx,
                                  conv_filters=rem_filters.get(i))
            rem.append(bc)
        new_cache["rem"] = rem
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     softcap=cfg.logit_softcap, ctx=ctx)
    return new_cache, logits


# ---------------------------------------------------------------------------
# Multi-token decode on the decode cache (speculative verify / replay)
#
# decode_chunk consumes up to C tokens per slot in ONE executable, returning
# logits at every position — the verify half of self-speculative decoding.
# Per-row `active_len` masks the advance: row b's states, conv tails, kv/ring
# buffers and position move by exactly active_len[b] tokens, positions past
# that are identity. Together with snapshot_cache_slots/restore_cache_slots
# this gives the rollback protocol: snapshot -> verify C tokens -> accept n
# -> restore -> replay with active_len = n.
# ---------------------------------------------------------------------------
def _decode_chunk_block(bp, bc, kind: str, x, pos, active_len,
                        cfg: ModelConfig, ctx: ShardCtx, conv_filters=None,
                        collect_states: bool = False):
    h = apply_norm(bp["norm1"], x, cfg.norm)
    window = cfg.window if kind == LOCAL_ATTN else 0
    aux = {}
    if kind in (ATTN, LOCAL_ATTN):
        kv = {k: bc[k] for k in ("k", "v", "slot_pos") if k in bc}
        kv, y = attn_mod.attention_decode_chunk(bp["mix"], kv, h, pos,
                                                active_len, cfg,
                                                window=window, ctx=ctx)
        bc = dict(bc, **kv)
    elif kind == HYENA:
        if "fut" in bc:           # FutureFill epoched exact decode
            sub = {k: bc[k] for k in ("conv", "kv", "fut", "epoch")}
            if conv_filters is None:
                conv_filters = hyena_mod.materialize_filters(
                    bp["mix"]["filter"], bc["kv"].shape[1], cfg.hyena)
            sub, y = hyena_mod.hyena_decode_epoch_chunk(
                bp["mix"], sub, h, pos, active_len, cfg, conv_filters,
                ctx=ctx)
        elif "kv" in bc:          # Lemma-2.1 cached-conv baseline
            sub = {k: bc[k] for k in ("conv", "kv")}
            if conv_filters is None:
                conv_filters = hyena_mod.materialize_filters(
                    bp["mix"]["filter"], bc["kv"].shape[1], cfg.hyena)
            sub, y = hyena_mod.hyena_decode_cached_conv_chunk(
                bp["mix"], sub, h, pos, active_len, cfg, conv_filters,
                ctx=ctx)
        else:                     # distilled modal recurrence
            sub = {k: bc[k] for k in ("conv", "x_re", "x_im")}
            if collect_states:
                sub, y, aux = hyena_mod.hyena_decode_chunk(
                    bp["mix"], sub, h, active_len, cfg, ctx=ctx,
                    return_states=True)
            else:
                sub, y = hyena_mod.hyena_decode_chunk(bp["mix"], sub, h,
                                                      active_len, cfg,
                                                      ctx=ctx)
        bc = dict(bc, **sub)
    elif kind == MAMBA2:
        sub = {k: bc[k] for k in ("conv", "ssm")}
        sub, y = ssm_mod.mamba2_decode_chunk(bp["mix"], sub, h, active_len,
                                             cfg, ctx=ctx)
        bc = dict(bc, **sub)
    elif kind == RGLRU:
        sub = {k: bc[k] for k in ("conv", "h")}
        sub, y = ssm_mod.rglru_decode_chunk(bp["mix"], sub, h, active_len,
                                            cfg, ctx=ctx)
        bc = dict(bc, **sub)
    else:
        raise ValueError(kind)
    x = x + y
    if cfg.d_ff > 0:
        h = apply_norm(bp["norm2"], x, cfg.norm)
        if cfg.mlp_kind == MLP_MOE:
            y, _ = moe_mod.moe_block(bp["mlp"], h, cfg.moe, ctx=ctx)
        else:
            y = apply_mlp(bp["mlp"], h, cfg.act, ctx=ctx)
        x = x + y
    if collect_states:
        return bc, x, aux
    return bc, x


def supports_state_select(cfg: ModelConfig, cache_kind: str = "native") -> bool:
    """True when decode_chunk(collect_states=True) can provide an O(1)
    selection-commit for this arch: every block is a distilled (native)
    Hyena layer, whose per-position modal states + conv windows identify the
    committed state at ANY accepted prefix length without a replay pass."""
    return (cfg.hyena is not None and cache_kind == "native"
            and not cfg.enc_dec and cfg.frontend == "none"
            and all(b == HYENA for b in cfg.blocks))


def decode_chunk(params, cache, tokens, cfg: ModelConfig, *, active_len,
                 ctx: ShardCtx = NOCTX, conv_filters=None,
                 need_logits: bool = True, collect_states: bool = False):
    """Multi-token decode step. tokens: (B, C) int32; cache must be a
    per-slot pool (pos (B,)); active_len (B,) in [0, C]. Returns
    (cache, logits (B, C, V)) — logits at EVERY chunk position (the
    speculative verifier needs them all; positions past a row's active_len
    yield garbage the caller masks). cache["pos"] advances by active_len.
    need_logits=False skips the final norm + unembed (the speculative
    commit replay only needs the state advance) and returns (cache, None).
    collect_states=True (requires `supports_state_select`) additionally
    returns a per-layer aux of per-position states for
    `commit_cache_from_states`: (cache, logits, aux)."""
    if cfg.enc_dec or cfg.frontend != "none":
        raise ValueError("decode_chunk does not support enc-dec/frontend "
                         "architectures")
    B, C = tokens.shape
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    pos = jnp.asarray(cache["pos"], jnp.int32)
    if pos.ndim != 1:
        raise ValueError("decode_chunk requires a per-slot cache "
                         "(init_cache(per_slot=True))")
    if collect_states and not supports_state_select(cfg):
        raise ValueError("collect_states requires a pure distilled-Hyena "
                         "arch (see supports_state_select)")
    active_len = jnp.asarray(active_len, jnp.int32)
    x = embed_tokens(params["embed"], tokens, ctx=ctx, dtype=dtype)
    if cfg.rope_theta <= 0.0:                    # learned absolute positions
        pe = params["embed"]["pos"]
        positions = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        x = x + jnp.take(pe, jnp.clip(positions, 0, pe.shape[0] - 1),
                         axis=0).astype(dtype)
    n_groups, n_rem = layer_layout(cfg)

    def body(x, gp_gc):
        gp, gc = gp_gc[0], gp_gc[1]
        gf = gp_gc[2] if len(gp_gc) > 2 else {}
        auxes = {}
        for i, kind in enumerate(cfg.pattern):
            out = _decode_chunk_block(gp[f"l{i}"], gc[f"l{i}"], kind, x, pos,
                                      active_len, cfg, ctx,
                                      conv_filters=gf.get(f"l{i}"),
                                      collect_states=collect_states)
            if collect_states:
                gc[f"l{i}"], x, auxes[f"l{i}"] = out
            else:
                gc[f"l{i}"], x = out
        return x, (gc, auxes)

    from repro import flags
    n_g = jax.tree.leaves(params["groups"])[0].shape[0]
    xs = (params["groups"], cache["groups"])
    if conv_filters is not None:
        xs = xs + (conv_filters["groups"],)
    x, (new_group_caches, group_aux) = jax.lax.scan(
        body, x, xs, unroll=flags.decode_unroll(n_g))
    new_cache = {"groups": new_group_caches, "pos": pos + active_len}
    aux = {"groups": group_aux, "pos": pos}
    if n_rem:
        rem_filters = (conv_filters or {}).get("rem", {})
        rem = []
        rem_aux = []
        for i in range(n_rem):
            kind = cfg.blocks[n_groups * len(cfg.pattern) + i]
            out = _decode_chunk_block(params["rem"][i], cache["rem"][i],
                                      kind, x, pos, active_len, cfg, ctx,
                                      conv_filters=rem_filters.get(i),
                                      collect_states=collect_states)
            if collect_states:
                bc, x, a = out
                rem_aux.append(a)
            else:
                bc, x = out
            rem.append(bc)
        new_cache["rem"] = rem
        aux["rem"] = rem_aux
    if not need_logits:
        return new_cache, None
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     softcap=cfg.logit_softcap, ctx=ctx)
    if collect_states:
        return new_cache, logits, aux
    return new_cache, logits


def commit_cache_from_states(aux, n_emit, cfg: ModelConfig):
    """Build the committed decode cache directly from a
    decode_chunk(collect_states=True) aux: per slot, select the modal state
    after exactly n_emit tokens and gather the conv tail ending there — an
    O(1) rollback-to-accepted-prefix with NO replay pass. Only valid for
    `supports_state_select` archs (pure distilled Hyena)."""
    from repro.models.layers import conv_tail_gather
    n_emit = jnp.asarray(n_emit, jnp.int32)
    w = cfg.hyena.short_conv - 1

    def sel_states(xs, seq_axis: int):
        # xs (..., B, C, D, d): state after j+1 tokens at index j
        idx = jnp.broadcast_to(
            (n_emit - 1).reshape((1,) * (seq_axis - 1) + (-1, 1, 1, 1)),
            xs.shape[:seq_axis] + (1,) + xs.shape[seq_axis + 1:])
        return jnp.take_along_axis(xs, idx, axis=seq_axis)[
            (slice(None),) * seq_axis + (0,)]

    def fix(a, seq_axis: int):
        ext = a["ext"]                           # (..., B, W-1+C, 3D)
        if seq_axis == 2:                        # leading group axis
            tail = jax.vmap(lambda e: conv_tail_gather(e, w, w + n_emit))(ext)
        else:
            tail = conv_tail_gather(ext, w, w + n_emit)
        return {"conv": tail,
                "x_re": sel_states(a["xs_re"], seq_axis),
                "x_im": sel_states(a["xs_im"], seq_axis)}

    out = {"groups": {lk: fix(lv, seq_axis=2)
                      for lk, lv in aux["groups"].items()},
           "pos": jnp.asarray(aux["pos"], jnp.int32) + n_emit}
    if aux.get("rem"):
        out["rem"] = [fix(a, seq_axis=1) for a in aux["rem"]]
    return out


# ---------------------------------------------------------------------------
# Snapshot / restore: the rollback half of speculative decoding
# ---------------------------------------------------------------------------
def _chunk_write_idx(pos, horizon: int, size: int, ring: bool):
    """(B, horizon) buffer indices a horizon-token advance writes per slot —
    the same index math attention_decode_chunk / the cached-conv chunk use."""
    offs = pos[:, None] + jnp.arange(horizon, dtype=jnp.int32)[None, :]
    return offs % size if ring else jnp.clip(offs, 0, size - 1)


def _gather_rows(leaf, idx, seq_axis: int):
    """Gather rows idx (B, C) along seq_axis; batch axis is seq_axis - 1."""
    B, C = idx.shape
    shape = [1] * leaf.ndim
    shape[seq_axis - 1] = B
    shape[seq_axis] = C
    tgt = leaf.shape[:seq_axis] + (C,) + leaf.shape[seq_axis + 1:]
    return jnp.take_along_axis(leaf, jnp.broadcast_to(idx.reshape(shape), tgt),
                               axis=seq_axis)


def _scatter_rows(leaf, idx, rows, seq_axis: int):
    b = jnp.arange(idx.shape[0])[:, None]                 # (B, 1) vs (B, C)
    rows = rows.astype(leaf.dtype)
    if seq_axis == 1:
        return leaf.at[b, idx].set(rows)
    assert seq_axis == 2, seq_axis
    return leaf.at[:, b, idx].set(rows)


_SEQ_KEYS = ("k", "v", "kv", "slot_pos")


def snapshot_cache_slots(cache, cfg: ModelConfig, horizon: int):
    """Capture everything a <= horizon-token advance (decode_step calls or
    one decode_chunk) can mutate, per slot: recurrent states and conv tails
    in full (they are O(1) per slot), plus the `horizon` rows of every
    sequence buffer (attention k/v linear or ring — slot_pos included — and
    cached-conv k.v products) at the write indices derived from the CURRENT
    cache["pos"]. restore_cache_slots with this snapshot is a bit-exact
    rollback to the snapshot point."""
    pos = jnp.asarray(cache["pos"], jnp.int32)
    if pos.ndim != 1:
        raise ValueError("snapshot_cache_slots requires a per-slot cache")

    def snap_block(c, seq_axis: int):
        out = {}
        ring = "slot_pos" in c
        for k, v in c.items():
            if k in ("cross_k", "cross_v"):
                continue                        # decode never mutates these
            if k in _SEQ_KEYS:
                idx = _chunk_write_idx(pos, horizon, v.shape[seq_axis], ring)
                out[k] = _gather_rows(v, idx, seq_axis)
            else:                               # conv / x_re / x_im / ssm / h
                out[k] = v
        return out

    snap = {"pos": pos,
            "groups": {lk: snap_block(lv, seq_axis=2)
                       for lk, lv in cache["groups"].items()}}
    if "rem" in cache:
        snap["rem"] = [snap_block(rc, seq_axis=1) for rc in cache["rem"]]
    return snap


def restore_cache_slots(cache, snap, cfg: ModelConfig):
    """Bit-exact rollback of a per-slot cache to a snapshot taken by
    snapshot_cache_slots: scatter the saved sequence-buffer rows back (ring
    slot_pos positions included), swap the saved recurrent states / conv
    tails in wholesale, and reset pos to the snapshot position."""
    pos = jnp.asarray(snap["pos"], jnp.int32)

    def rest_block(c, s, seq_axis: int):
        out = dict(c)
        ring = "slot_pos" in c
        for k, v in s.items():
            if k in _SEQ_KEYS:
                idx = _chunk_write_idx(pos, v.shape[seq_axis],
                                       c[k].shape[seq_axis], ring)
                out[k] = _scatter_rows(c[k], idx, v, seq_axis)
            else:
                out[k] = v
        return out

    out = {"groups": {lk: rest_block(lv, snap["groups"][lk], seq_axis=2)
                      for lk, lv in cache["groups"].items()},
           "pos": pos}
    if "rem" in cache:
        out["rem"] = [rest_block(rc, snap["rem"][i], seq_axis=1)
                      for i, rc in enumerate(cache["rem"])]
    return out


# ---------------------------------------------------------------------------
# Prefill: full-sequence pass that fills the decode caches
# ---------------------------------------------------------------------------
def _ring_from_linear(leaf, seq_axis: int, eff: int, lens):
    """Re-layout a linear (..., T, ...) buffer into ring-slot order.

    Ring slot j of row b holds the absolute position p ≡ j (mod eff) from the
    window [len_b - eff, len_b); slots whose position is negative (prompt
    shorter than the ring) are zeroed and marked -1 in slot_pos. The batch
    axis is seq_axis - 1; lens is (B,). Returns (ring, slot_pos (B, eff)).
    """
    B = lens.shape[0]
    j = jnp.arange(eff)
    base = lens[:, None] - eff                           # (B, 1), may be < 0
    p = base + ((j[None, :] - base) % eff)               # (B, eff)
    valid = p >= 0
    sp = jnp.where(valid, p, -1).astype(jnp.int32)
    idx = jnp.clip(p, 0, leaf.shape[seq_axis] - 1)
    shape = [1] * leaf.ndim
    shape[seq_axis - 1] = B
    shape[seq_axis] = eff
    tgt = leaf.shape[:seq_axis] + (eff,) + leaf.shape[seq_axis + 1:]
    ring = jnp.take_along_axis(leaf, jnp.broadcast_to(idx.reshape(shape), tgt),
                               axis=seq_axis)
    ring = jnp.where(jnp.broadcast_to(valid.reshape(shape), tgt), ring, 0)
    return ring, sp


def prefill(params, tokens, cfg: ModelConfig, max_len: int, *,
            ctx: ShardCtx = NOCTX, frontend=None, moe_impl: str = "dropless",
            cache_kind: str = "native", lengths=None):
    """Process prompt, return (cache, last_logits).

    Attention k/v from the forward pass are padded into max_len cache buffers;
    recurrent blocks produce O(1) states directly (Sec. 3.4 fast pre-filling).
    With cache_kind="conv", Hyena layers cache the k.v product sequence for
    the Lemma-2.1 cached-conv decode baseline instead of the modal state.

    `lengths` (B,) enables bucketed batch prefill: rows are right-padded to a
    shared bucket length T, caches are masked to each row's true length, the
    cache position becomes a per-row (B,) vector, and last_logits is taken at
    each row's own last real position. One executable then serves every
    prompt length in the bucket.
    """
    B, T = tokens.shape
    logits, _, (scan_caches, rem_caches) = forward(
        params, tokens, cfg, ctx=ctx, frontend=frontend, moe_impl=moe_impl,
        collect_cache=True, remat="none", cache_kind=cache_kind,
        lengths=lengths, filter_len=max_len)
    if frontend is not None and not cfg.enc_dec:
        T = T + frontend.shape[1]              # VLM: patches occupy kv positions
    lens = (jnp.full((B,), T, jnp.int32) if lengths is None
            else jnp.asarray(lengths, jnp.int32))

    def fix_cache(c, kind: str, seq_axis: int):
        eff = max_len
        if kind == LOCAL_ATTN and 0 < cfg.window < max_len:
            eff = cfg.window
        out = {}
        for k, v in c.items():
            if k in ("k", "v"):
                if eff < max_len:
                    ring, sp = _ring_from_linear(v.astype(jnp.bfloat16),
                                                 seq_axis, eff, lens)
                    out[k] = ring
                    # slot_pos is per batch row: (B, eff) / (n_groups, B, eff)
                    out["slot_pos"] = jnp.broadcast_to(
                        sp, v.shape[:seq_axis - 1] + (B, eff))
                else:
                    pad = [(0, 0)] * v.ndim
                    pad[seq_axis] = (0, max_len - v.shape[seq_axis])
                    out[k] = jnp.pad(v.astype(jnp.bfloat16), pad)
            elif k in ("kv", "fut"):   # hyena conv/epoch sequence buffers
                pad = [(0, 0)] * v.ndim
                pad[seq_axis] = (0, max_len - v.shape[seq_axis])
                out[k] = jnp.pad(v, pad)
            elif k in ("cross_k", "cross_v"):
                out[k] = v.astype(jnp.bfloat16)
            elif k != "slot_pos":
                out[k] = v
        return out

    groups = {lk: fix_cache(lv, cfg.pattern[int(lk[1:])], seq_axis=2)
              for lk, lv in scan_caches.items()}
    pos = jnp.asarray(T, jnp.int32) if lengths is None else lens
    cache = {"groups": groups, "pos": pos}
    n_groups, n_rem = layer_layout(cfg)
    if n_rem:
        cache["rem"] = [
            fix_cache(rc, cfg.blocks[n_groups * len(cfg.pattern) + i], seq_axis=1)
            for i, rc in enumerate(rem_caches)
        ]
    if lengths is None:
        return cache, logits[:, -1, :]
    last = jnp.take_along_axis(logits, (lens - 1)[:, None, None], axis=1)
    return cache, last[:, 0, :]


def materialize_conv_filters(params, cfg: ModelConfig, max_len: int):
    """Pre-materialize every Hyena layer's long filters at max_len for the
    cached-conv decode path. One-time engine-setup cost; pass the result to
    `decode_step(conv_filters=...)` so the hot loop doesn't re-run the
    filter MLP each token. Layout mirrors the cache: {"groups": {l_i:
    (h (G,M,L), h0 (G,M))}, "rem": {i: (h, h0)}}."""
    hcfg = cfg.hyena
    n_groups, n_rem = layer_layout(cfg)
    out: Dict[str, Any] = {"groups": {}}
    for i, kind in enumerate(cfg.pattern):
        if kind == HYENA:
            out["groups"][f"l{i}"] = jax.vmap(
                lambda fp: hyena_mod.materialize_filters(fp, max_len, hcfg))(
                    params["groups"][f"l{i}"]["mix"]["filter"])
    rem = {}
    for i in range(n_rem):
        if cfg.blocks[n_groups * len(cfg.pattern) + i] == HYENA:
            rem[i] = hyena_mod.materialize_filters(
                params["rem"][i]["mix"]["filter"], max_len, hcfg)
    if rem:
        out["rem"] = rem
    return out


# ---------------------------------------------------------------------------
# Chunked (resumable) prefill: consume a prompt in fixed-size chunks
#
# One chunk-shaped executable covers arbitrarily long prompts, so a serving
# engine can interleave long-prompt admission with decode ticks (FutureFill /
# Flash-Inference-style blocked prompt processing). The scratch cache differs
# from the decode cache in two ways: Hyena layers carry the k.v product
# history so cross-chunk contributions use the TRUE long filter (exact — not
# the distilled approximation; the modal state is advanced alongside), and
# windowed attention keeps a full linear buffer (ring layout is produced at
# finalize). Buffers are rounded up to a whole number of chunks so the final
# (padded) chunk's writes never clamp.
# ---------------------------------------------------------------------------
def _prefill_buf_len(max_len: int, chunk: int) -> int:
    return ((max_len + chunk - 1) // chunk) * chunk


def _init_block_prefill_cache(kind: str, cfg: ModelConfig, batch: int,
                              buf_len: int, cache_kind: str):
    c: Dict[str, Any] = {}
    if kind in (ATTN, LOCAL_ATTN):
        # f32 scratch: the decode cache is bf16, but chunked prefill re-reads
        # past keys for in-chunk attention — downcast only at finalize
        c["k"] = Param(jnp.zeros((batch, buf_len, cfg.n_kv_heads, cfg.hd),
                                 jnp.float32),
                       ("batch", "kv_seq", "kv_heads", None))
        c["v"] = Param(jnp.zeros((batch, buf_len, cfg.n_kv_heads, cfg.hd),
                                 jnp.float32),
                       ("batch", "kv_seq", "kv_heads", None))
    elif kind == HYENA:
        hc = hyena_mod.init_hyena_conv_cache(batch, buf_len, cfg)
        c["conv"] = Param(hc["conv"], ("batch", None, "qkv"))
        c["kv"] = Param(hc["kv"], ("batch", "kv_seq", "qkv"))
        if cache_kind == "native":
            nc = hyena_mod.init_hyena_cache(batch, cfg)
            c["x_re"] = Param(nc["x_re"], ("batch", "qkv", "state"))
            c["x_im"] = Param(nc["x_im"], ("batch", "qkv", "state"))
    elif kind == MAMBA2:
        mc = ssm_mod.init_mamba2_cache(batch, cfg)
        c["conv"] = Param(mc["conv"], ("batch", None, "mlp"))
        c["ssm"] = Param(mc["ssm"], ("batch", "heads", None, "state"))
    elif kind == RGLRU:
        rc = ssm_mod.init_rglru_cache(batch, cfg)
        c["conv"] = Param(rc["conv"], ("batch", None, "mlp"))
        c["h"] = Param(rc["h"], ("batch", "mlp"))
    else:
        raise ValueError(kind)
    return c


def init_prefill_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                       chunk: int, cache_kind: str = "native"):
    """Param-tree of chunked-prefill scratch state (see section comment)."""
    if cfg.enc_dec or cfg.frontend != "none":
        raise ValueError("chunked prefill does not support enc-dec/frontend "
                         "architectures")
    buf_len = _prefill_buf_len(max_len, chunk)
    n_groups, n_rem = layer_layout(cfg)
    group = {f"l{i}": _init_block_prefill_cache(kind, cfg, batch, buf_len,
                                                cache_kind)
             for i, kind in enumerate(cfg.pattern)}
    stacked = jax.tree.map(
        lambda p: Param(jnp.broadcast_to(p.value, (n_groups,) + p.value.shape),
                        (None,) + tuple(p.axes)),
        group, is_leaf=is_param)
    cache: Dict[str, Any] = {"groups": stacked}
    if n_rem:
        cache["rem"] = [
            _init_block_prefill_cache(
                cfg.blocks[n_groups * len(cfg.pattern) + i], cfg, batch,
                buf_len, cache_kind)
            for i in range(n_rem)
        ]
    return cache


def _prefill_chunk_block(bp, bc, kind: str, x, positions, start, chunk_len,
                         cfg: ModelConfig, max_len: int, ctx: ShardCtx, *,
                         conv_filters=None, cache_kind: str = "native"):
    """One block over one prompt chunk. Mirrors _decode_block's structure."""
    h = apply_norm(bp["norm1"], x, cfg.norm)
    window = cfg.window if kind == LOCAL_ATTN else 0
    if kind in (ATTN, LOCAL_ATTN):
        sub = {k: bc[k] for k in ("k", "v")}
        sub, y = attn_mod.attention_prefill_chunk(
            bp["mix"], sub, h, positions, start, chunk_len, cfg,
            window=window, ctx=ctx)
    elif kind == HYENA:
        keys = ("conv", "kv") if "x_re" not in bc else ("conv", "kv", "x_re",
                                                        "x_im")
        sub = {k: bc[k] for k in keys}
        if conv_filters is None:       # fallback: re-materialize every chunk
            # at max_len, NOT the buffer length — the implicit filter's
            # values depend on the materialization length, and every other
            # serving path pins it to max_len (filter_len)
            conv_filters = hyena_mod.materialize_filters(
                bp["mix"]["filter"], max_len, cfg.hyena)
        sub, y = hyena_mod.hyena_prefill_chunk(
            bp["mix"], sub, h, start, chunk_len, cfg, conv_filters, ctx=ctx,
            cache_kind="conv" if "x_re" not in bc else "native")
    elif kind == MAMBA2:
        sub = {k: bc[k] for k in ("conv", "ssm")}
        sub, y = ssm_mod.mamba2_prefill_chunk(bp["mix"], sub, h, chunk_len,
                                              cfg, ctx=ctx)
    elif kind == RGLRU:
        sub = {k: bc[k] for k in ("conv", "h")}
        sub, y = ssm_mod.rglru_prefill_chunk(bp["mix"], sub, h, chunk_len,
                                             cfg, ctx=ctx)
    else:
        raise ValueError(kind)
    bc = dict(bc, **sub)
    x = x + y
    if cfg.d_ff > 0:
        h = apply_norm(bp["norm2"], x, cfg.norm)
        if cfg.mlp_kind == MLP_MOE:
            y, _ = moe_mod.moe_block(bp["mlp"], h, cfg.moe, ctx=ctx)
        else:
            y = apply_mlp(bp["mlp"], h, cfg.act, ctx=ctx)
        x = x + y
    return bc, x


def prefill_from_cache(params, cache, tokens, start_pos, cfg: ModelConfig,
                       max_len: int, *, chunk_len=None, ctx: ShardCtx = NOCTX,
                       conv_filters=None, cache_kind: str = "native"):
    """Resumable prefill: consume the prompt slice tokens (B, C) occupying
    absolute positions [start_pos, start_pos + chunk_len).

    `cache` comes from `init_prefill_cache` (first chunk) or a previous call;
    `chunk_len` (traced scalar, default C) marks the real positions of a
    padded final chunk — one chunk-shaped executable serves every prompt
    length. `conv_filters` (materialize_conv_filters at the buffer length or
    longer) avoids re-running the Hyena filter MLP per chunk. Returns
    (cache, last_logits (B, V)) with logits taken at the chunk's last real
    position; hand the finished cache to `finalize_prefill_cache`.
    """
    B, C = tokens.shape
    if chunk_len is None:
        chunk_len = C
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    start = jnp.asarray(start_pos, jnp.int32)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = embed_tokens(params["embed"], tokens, ctx=ctx, dtype=dtype)
    if cfg.rope_theta <= 0.0:                    # learned absolute positions
        pe = params["embed"]["pos"]
        x = x + jax.lax.dynamic_slice_in_dim(pe, start, C,
                                             axis=0)[None].astype(dtype)
    positions = jnp.broadcast_to(start + jnp.arange(C)[None, :], (B, C))
    n_groups, n_rem = layer_layout(cfg)

    def body(x, gp_gc):
        gp, gc = gp_gc[0], gp_gc[1]
        gf = gp_gc[2] if len(gp_gc) > 2 else {}
        for i, kind in enumerate(cfg.pattern):
            gc[f"l{i}"], x = _prefill_chunk_block(
                gp[f"l{i}"], gc[f"l{i}"], kind, x, positions, start,
                chunk_len, cfg, max_len, ctx, conv_filters=gf.get(f"l{i}"),
                cache_kind=cache_kind)
        return x, gc

    from repro import flags
    n_g = jax.tree.leaves(params["groups"])[0].shape[0]
    xs = (params["groups"], cache["groups"])
    if conv_filters is not None:
        xs = xs + (conv_filters["groups"],)
    x, new_group_caches = jax.lax.scan(body, x, xs,
                                       unroll=flags.scan_unroll(n_g))
    new_cache = {"groups": new_group_caches}
    if n_rem:
        rem_filters = (conv_filters or {}).get("rem", {})
        rem = []
        for i in range(n_rem):
            kind = cfg.blocks[n_groups * len(cfg.pattern) + i]
            bc, x = _prefill_chunk_block(
                params["rem"][i], cache["rem"][i], kind, x, positions, start,
                chunk_len, cfg, max_len, ctx, conv_filters=rem_filters.get(i),
                cache_kind=cache_kind)
            rem.append(bc)
        new_cache["rem"] = rem
    x = jax.lax.dynamic_slice_in_dim(x, chunk_len - 1, 1, axis=1)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     softcap=cfg.logit_softcap, ctx=ctx)
    return new_cache, logits[:, 0, :]


def finalize_prefill_cache(cache, length, cfg: ModelConfig, max_len: int, *,
                           cache_kind: str = "native"):
    """Convert finished chunked-prefill scratch into a decode cache: drop the
    Hyena k.v history for the distilled kind, trim buffers to max_len,
    downcast attention k/v to bf16, re-layout windowed attention into ring
    form, and set pos = `length` (the prompt length, traced scalar)."""
    length = jnp.asarray(length, jnp.int32)

    def trim(v, seq_axis: int, to_len: int):
        return jax.lax.slice_in_dim(v, 0, to_len, axis=seq_axis)

    def fix(c, kind: str, seq_axis: int):
        B = jax.tree.leaves(c)[0].shape[seq_axis - 1]
        lens = jnp.full((B,), length, jnp.int32)
        out = dict(c)
        if kind in (ATTN, LOCAL_ATTN):
            eff = max_len
            if kind == LOCAL_ATTN and 0 < cfg.window < max_len:
                eff = cfg.window
            if eff < max_len:
                ring_k, sp = _ring_from_linear(c["k"].astype(jnp.bfloat16),
                                               seq_axis, eff, lens)
                ring_v, _ = _ring_from_linear(c["v"].astype(jnp.bfloat16),
                                              seq_axis, eff, lens)
                out = {"k": ring_k, "v": ring_v,
                       "slot_pos": jnp.broadcast_to(
                           sp, c["k"].shape[:seq_axis - 1] + (B, eff))}
            else:
                out = {"k": trim(c["k"], seq_axis, max_len).astype(jnp.bfloat16),
                       "v": trim(c["v"], seq_axis, max_len).astype(jnp.bfloat16)}
        elif kind == HYENA:
            if "x_re" in c:                       # distilled: drop kv history
                out = {"conv": c["conv"], "x_re": c["x_re"], "x_im": c["x_im"]}
            else:
                out = {"conv": c["conv"],
                       "kv": trim(c["kv"], seq_axis, max_len)}
                if cache_kind == "epoch":
                    # fresh FutureFill state: epoch 0 / fut empty — the first
                    # decode tick's flush bakes the prefix in (exact either
                    # way; see hyena_decode_epoch)
                    out["fut"] = jnp.zeros_like(out["kv"])
                    out["epoch"] = jnp.zeros(
                        c["kv"].shape[:seq_axis - 1] + (B,), jnp.int32)
        return out

    groups = {lk: fix(lv, cfg.pattern[int(lk[1:])], seq_axis=2)
              for lk, lv in cache["groups"].items()}
    n_groups, n_rem = layer_layout(cfg)
    out = {"groups": groups, "pos": length}
    if n_rem:
        out["rem"] = [
            fix(rc, cfg.blocks[n_groups * len(cfg.pattern) + i], seq_axis=1)
            for i, rc in enumerate(cache["rem"])
        ]
    return out


# ---------------------------------------------------------------------------
# Slot-indexed cache helpers (continuous-batching serving engine)
#
# A pooled cache (init_cache(..., per_slot=True)) holds one request per batch
# row ("slot"). Admission scatters a freshly prefilled batch=1 cache into a
# free slot; eviction just frees the slot — its stale state is fully
# overwritten on readmission (reset_cache_slot exists for explicit hygiene).
# ---------------------------------------------------------------------------
def _slot_update(axis: int, slot):
    def f(pool_leaf, single_leaf):
        return jax.lax.dynamic_update_slice_in_dim(
            pool_leaf, single_leaf.astype(pool_leaf.dtype), slot, axis=axis)
    return f


def write_cache_slot(pool, single, slot):
    """Scatter a batch=1 cache (from `prefill`) into row `slot` of a pooled
    per-slot cache. Group leaves carry a leading layer axis, so their batch
    axis is 1; remainder leaves and `pos` use axis 0. jit-friendly (traced
    `slot`)."""
    slot = jnp.asarray(slot, jnp.int32)
    out = {"groups": jax.tree.map(_slot_update(1, slot), pool["groups"],
                                  single["groups"]),
           "pos": pool["pos"].at[slot].set(
               jnp.asarray(single["pos"], jnp.int32))}
    if "rem" in pool:
        out["rem"] = jax.tree.map(_slot_update(0, slot), pool["rem"],
                                  single["rem"])
    return out


def write_cache_slots(pool, multi, slots):
    """Scatter a batch=K prefilled cache (from `prefill(..., lengths=...)`)
    into rows `slots` (K,) of a pooled per-slot cache in ONE call — the
    bucketed batch-admission path. Rows whose slot index falls outside
    [0, n_slots) are dummy padding (the engine pads an admission batch to a
    fixed size by pointing dummies at slot index n_slots) and must not touch
    the pool. That drop is an EXPLICIT mask, not out-of-bounds scatter
    semantics: under a sharded pool each partition sees shifted local
    indices, so `.at[...].set(mode="drop")` would drop or clamp different
    rows per shard. A scatter-max marker records per pool row the index of
    the last valid admission row targeting it (-1 = untouched; dummy rows
    contribute -1 so they can never override a valid update), and each leaf
    takes a masked gather against it. jit-friendly (traced `slots`);
    `multi["pos"]` must be a (K,) vector."""
    slots = jnp.asarray(slots, jnp.int32)
    K = slots.shape[0]
    B = pool["pos"].shape[0]
    valid = (slots >= 0) & (slots < B)
    src = jnp.where(valid, jnp.arange(K, dtype=jnp.int32), -1)
    marker = jnp.full((B,), -1, jnp.int32).at[
        jnp.where(valid, slots, 0)].max(src)
    take_idx = jnp.maximum(marker, 0)
    keep = marker >= 0

    def upd(axis: int):
        def f(pool_leaf, multi_leaf):
            vals = jnp.take(multi_leaf.astype(pool_leaf.dtype), take_idx,
                            axis=axis)
            mask = keep.reshape((1,) * axis + (B,)
                                + (1,) * (pool_leaf.ndim - axis - 1))
            return jnp.where(mask, vals, pool_leaf)
        return f

    out = {"groups": jax.tree.map(upd(1), pool["groups"], multi["groups"]),
           "pos": jnp.where(keep,
                            jnp.take(jnp.asarray(multi["pos"], jnp.int32),
                                     take_idx),
                            pool["pos"])}
    if "rem" in pool:
        out["rem"] = jax.tree.map(upd(0), pool["rem"], multi["rem"])
    return out


def gather_cache_rows(cache, rows):
    """Gather batch rows `rows` (R,) int32 from a pooled per-slot cache (or a
    `decode_chunk(collect_states=True)` aux, which shares the same axis
    conventions: group leaves carry a leading layer axis so their batch axis
    is 1; remainder leaves and `pos` use axis 0). Rows may repeat — the tree
    speculative verifier replicates each slot once per draft branch
    (`rows = repeat(arange(B), branch)`) and later selects the winning
    branch per slot (`rows = arange(B) * branch + winner`). jit-friendly
    (traced `rows`)."""
    rows = jnp.asarray(rows, jnp.int32)
    out = {"groups": jax.tree.map(lambda x: jnp.take(x, rows, axis=1),
                                  cache["groups"]),
           "pos": jnp.take(jnp.asarray(cache["pos"], jnp.int32), rows,
                           axis=0)}
    if cache.get("rem"):
        out["rem"] = jax.tree.map(lambda x: jnp.take(x, rows, axis=0),
                                  cache["rem"])
    return out


# ---------------------------------------------------------------------------
# State-integrity guards (resilient serving)
# ---------------------------------------------------------------------------
_STATE_NORM_KEYS = ("x_re", "x_im")      # modal state: pole bound applies


def modal_state_bound(params, cfg: ModelConfig, *, margin: float = 1e3):
    """Host-side bound on the distilled modal-state magnitude.

    Prop. 3.3's recurrence x_{t+1} = λ x_t + R u_t with stable poles
    (|λ| < 1) keeps |x| ≤ max|Ru| / (1 - max|λ|); `margin` stands in for
    the data-dependent max|Ru| term, so the bound only trips on genuine
    divergence (corrupted state / unstable pole), never on healthy
    activations. Returns inf when the arch has no distilled Hyena params
    (finiteness-only guard). Pure host computation — call once at engine
    init, not per tick.
    """
    if cfg.hyena is None:
        return float("inf")
    max_log_a = None

    def walk(node):
        nonlocal max_log_a
        if isinstance(node, dict):
            dp = node.get("distilled")
            if isinstance(dp, dict) and "log_a" in dp:
                la = dp["log_a"]
                la = getattr(la, "value", la)
                m = float(jnp.max(la))
                max_log_a = m if max_log_a is None else max(max_log_a, m)
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    if max_log_a is None:
        return float("inf")
    max_pole = math.exp(max_log_a)
    if max_pole >= 1.0 - 1e-6:             # nominally unstable pole: only a
        return margin * 1e3                # runaway state should trip this
    return margin / (1.0 - max_pole)


def slot_health(cache, logits, bound):
    """Per-slot state-integrity bitvector (B,) bool: True = healthy.

    O(B·state) reductions over the SMALL per-slot leaves only — recurrent
    states and conv tails — plus per-row finiteness of this tick's logits.
    The large sequence buffers (_SEQ_KEYS: attention k/v rings, cached-conv
    kv) are deliberately skipped: a NaN/Inf row there poisons the attention
    softmax / conv sum and therefore surfaces in that slot's logits row, so
    the logits check covers them without O(max_len) reductions. The modal
    state (x_re/x_im) is additionally checked against `bound`
    (modal_state_bound); pass inf to disable the norm check. Operates on a
    raw (unzipped) per-slot cache; fuse into the dispatch jit so the
    bitvector rides back with the sampled tokens.
    """
    # ONE reduction, not one per leaf: on CPU every extra XLA op pays a
    # parallel-loop dispatch that dwarfs the actual FLOPs (a per-leaf
    # formulation costs ~40% of a decode step; this form is ~3%). Leaves
    # that only need finiteness are scaled by 0 (finite -> 0, Inf/NaN ->
    # NaN); modal-state leaves by 1/bound (so the pole bound becomes <= 1,
    # and bound=inf degrades to finiteness: x/inf is 0 finite, NaN for
    # Inf/NaN). One concat + max per slot; NaN propagates through max and
    # fails the <= compare.
    B = logits.shape[0]
    parts = [logits.astype(jnp.float32).reshape(B, -1) * 0.0]

    def add_block(c, batch_axis: int):
        for k, v in c.items():
            if k in _SEQ_KEYS or k in ("cross_k", "cross_v", "fut"):
                # `fut` is an O(max_len) buffer like kv: corruption reaches
                # the slot's logits row additively, so the logits check
                # covers it without an O(max_len) reduction here
                continue
            if not jnp.issubdtype(v.dtype, jnp.inexact):
                continue
            vf = jnp.moveaxis(v, batch_axis, 0).reshape(B, -1)
            vf = vf.astype(jnp.float32)
            scale = (1.0 / bound) if k in _STATE_NORM_KEYS else 0.0
            parts.append(vf * scale)

    for lv in cache["groups"].values():
        add_block(lv, batch_axis=1)
    for rc in cache.get("rem") or []:
        add_block(rc, batch_axis=0)
    m = jnp.max(jnp.abs(jnp.concatenate(parts, axis=1)), axis=1)
    return m <= 1.0


def reset_cache_slot(pool, slot):
    """Zero row `slot` of a pooled cache (ring slot_pos rows to -1, pos 0)."""
    from jax.tree_util import DictKey, tree_map_with_path
    slot = jnp.asarray(slot, jnp.int32)

    def rz(axis: int):
        def f(path, leaf):
            is_sp = any(isinstance(k, DictKey) and k.key == "slot_pos"
                        for k in path)
            row = jnp.full(leaf.shape[:axis] + (1,) + leaf.shape[axis + 1:],
                           -1 if is_sp else 0, leaf.dtype)
            return jax.lax.dynamic_update_slice_in_dim(leaf, row, slot,
                                                       axis=axis)
        return f

    out = {"groups": tree_map_with_path(rz(1), pool["groups"]),
           "pos": pool["pos"].at[slot].set(0)}
    if "rem" in pool:
        out["rem"] = tree_map_with_path(rz(0), pool["rem"])
    return out
