"""Distillation quality: exact recovery, order monotonicity, init comparison,
truncation baselines (App. E.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import balanced_truncation, eval_filter, init_modal, modal_truncation
from repro.core.distill import distill_filters, fit_residues, kung_init
from repro.core.truncation import balanced_truncation_modal


def _rel_err(ssm, h):
    hh = eval_filter(ssm, h.shape[-1])
    return jnp.linalg.norm(hh - h, axis=-1) / jnp.linalg.norm(h, axis=-1)


@pytest.fixture(scope="module")
def target():
    true = init_modal(jax.random.PRNGKey(0), (2,), 6, r_minmax=(0.5, 0.92))
    return eval_filter(true, 384)


def test_exact_recovery_same_order(target):
    ssm, _ = distill_filters(target, 6, steps=1500)
    err = _rel_err(ssm, target)
    assert float(jnp.max(err)) < 0.05, err


def test_error_decreases_with_order(target):
    errs = []
    for m in (1, 2, 4, 6):
        ssm, _ = distill_filters(target, m, steps=600)
        errs.append(float(jnp.max(_rel_err(ssm, target))))
    assert errs[-1] < errs[0]
    # loosely monotone (gradient noise tolerance)
    assert errs[2] <= errs[0] + 1e-3 and errs[3] <= errs[1] + 1e-3


def test_kung_init_beats_random_init_start(target):
    """Kung warm start should begin at much lower loss than random init."""
    kg = kung_init(target, 6)
    rd = init_modal(jax.random.PRNGKey(1), (2,), 6)
    rd = rd._replace(h0=target[..., 0])
    assert float(jnp.max(_rel_err(kg, target))) < \
        float(jnp.max(_rel_err(rd, target)))


def test_fit_residues_is_optimal_given_true_poles(target):
    """With the exact poles, the linear residue solve nearly interpolates."""
    true = init_modal(jax.random.PRNGKey(0), (2,), 6, r_minmax=(0.5, 0.92))
    R = fit_residues(true.poles(), target)
    refit = true._replace(R_re=jnp.real(R), R_im=jnp.imag(R))
    assert float(jnp.max(_rel_err(refit, target))) < 1e-3


def test_balanced_truncation_baseline(target):
    """App. E.3.2: Kung balanced realization reproduces the filter at full
    order and degrades gracefully at low order."""
    h = np.asarray(target[0])
    A, B, C, h0 = balanced_truncation(jnp.asarray(h), 12)
    # impulse response of the realization
    x = B
    imp = [float(h0)]
    for _ in range(len(h) - 1):
        imp.append(float(C @ x))
        x = A @ x
    rel = np.linalg.norm(np.array(imp) - h) / np.linalg.norm(h)
    assert rel < 0.05, rel


def test_modal_truncation_ranking(target):
    ssm, _ = distill_filters(target, 6, steps=800)
    tr = modal_truncation(ssm, 3, refit=True, h=target)
    assert tr.log_a.shape[-1] == 3
    # truncation error bounded by the discarded-mode influence (E.2 spirit)
    full = float(jnp.max(_rel_err(ssm, target)))
    trunc = float(jnp.max(_rel_err(tr, target)))
    assert trunc >= full - 1e-5
    assert trunc < 1.0


def test_h2_equals_l2_objective(target):
    """Parseval: H2- and l2-distilled systems reach similar errors."""
    s1, _ = distill_filters(target, 4, steps=600, objective="l2")
    s2, _ = distill_filters(target, 4, steps=600, objective="h2")
    e1 = float(jnp.max(_rel_err(s1, target)))
    e2 = float(jnp.max(_rel_err(s2, target)))
    assert abs(e1 - e2) < 0.15, (e1, e2)
