"""Pure-jnp oracle: causal GQA attention."""
import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd) -> (B,S,Hq,hd)."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) / np.sqrt(hd)
    if causal:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(T)[None, :]
        m = kpos <= qpos
        if window > 0:
            m = m & (kpos > qpos - window)
        s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return o.reshape(B, S, Hq, hd)
