from repro.distributed.sharding import (  # noqa: F401
    Param, unzip, zip_specs, ShardingRules, TRAIN_RULES, SERVE_RULES,
    resolve_spec, tree_specs, constrain,
)
