"""Fig 5.4: generation cache memory vs number of generated tokens.

Transformer kv-cache grows O(L); cached-conv Hyena grows O(L); the distilled
recurrence is constant O(d). Measured as actual cache-tree bytes, plus the
analytic footprint at the paper's 1.3B scale.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import row
from benchmarks.models import build, hyena_cfg, transformer_cfg
from repro.configs import get_config
from repro.models.model import init_cache
from repro.distributed.sharding import unzip

BATCH = 8


def _bytes(tree):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def main(out):
    tcfg, hcfg = transformer_cfg(), hyena_cfg()
    for K in (128, 512, 2048):
        tkv, _ = unzip(init_cache(tcfg, BATCH, K))
        hst, _ = unzip(init_cache(hcfg, BATCH, K))
        out(row(f"fig5.4/transformer_kv/K{K}", 0.0,
                f"cache_MB={_bytes(tkv)/1e6:.2f}"))
        out(row(f"fig5.4/laughinghyena/K{K}", 0.0,
                f"cache_MB={_bytes(hst)/1e6:.2f}"))
    # analytic at paper scale (1.3B, batch 64, fp16): Sec. 5.4
    cfg = get_config("multihyena-1.3b")
    d = cfg.hyena.distill_order
    state = 64 * cfg.n_layers * cfg.d_model * d * 2 * 2          # re+im fp16
    conv = 64 * cfg.n_layers * 3 * cfg.d_model * 2 * 2
    out(row("fig5.4/analytic_1.3b_b64/laughinghyena", 0.0,
            f"cache_MB={(state+conv)/1e6:.0f}"))
    for K in (256, 1024, 4096):
        kv = 64 * cfg.n_layers * K * 2 * cfg.n_kv_heads * cfg.hd * 2
        out(row(f"fig5.4/analytic_1.3b_b64/transformer_K{K}", 0.0,
                f"cache_MB={kv/1e6:.0f}"))
