"""Transfer-function machinery (paper App. A).

Rational form H(z) = (b_1 z^-1 + ... + b_d z^-d)/(1 + a_1 z^-1 + ... ) + h0,
companion canonical realization (App. A.5), fast O~(L) evaluation on the
roots of unity (Lemma A.6), state-space -> transfer-function conversion
(App. A.6, Listing 1) and the O(d) companion recurrence (Lemma A.7,
Listing 2).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def poly_from_roots(roots: jnp.ndarray) -> jnp.ndarray:
    """Monic polynomial coefficients from roots.

    roots: (..., d) complex -> coeffs (..., d+1), c[0] = 1 (descending powers:
    p(z) = z^d + c1 z^(d-1) + ... + cd). Sequential convolution; d is small.
    """
    d = roots.shape[-1]
    batch = roots.shape[:-1]
    c = jnp.zeros(batch + (d + 1,), roots.dtype).at[..., 0].set(1.0)
    for n in range(d):
        r = roots[..., n][..., None]
        shifted = jnp.roll(c, 1, axis=-1).at[..., 0].set(0.0)
        c = c - r * shifted
    return c


def tf_from_modal(lam: jnp.ndarray, R: jnp.ndarray, h0: jnp.ndarray,
                  conjugate_complete: bool = True
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Modal (poles/residues) -> rational coefficients (a, b).

    H(z) = h0 + sum_n R_n / (z - lam_n) = h0 + q(z)/p(z) with
    q_n(z) = prod_{m != n} (z - lam_m);  q = sum_n R_n q_n (degree d-1).

    The modal form h = Re[sum R lam^t] is the transfer function of the
    conjugate-completed system {(lam, R/2)} U {(lam*, R*/2)} (App. B.1), so
    with conjugate_complete=True (default) the returned coefficients describe
    that real system of order 2d (real up to roundoff).
    """
    if conjugate_complete:
        lam = jnp.concatenate([lam, jnp.conj(lam)], axis=-1)
        R = jnp.concatenate([R / 2.0, jnp.conj(R) / 2.0], axis=-1)
    d = lam.shape[-1]
    a = poly_from_roots(lam)                               # (..., d+1)
    # q_n via deflation: divide p by (z - lam_n) synthetically.
    def deflate(a_full, r):
        # synthetic division of monic poly (.., d+1) by (z - r) -> (.., d)
        def body(carry, coef):
            q = coef + r * carry
            return q, q
        init = jnp.zeros_like(r)
        _, qs = jax.lax.scan(body, init, jnp.moveaxis(a_full[..., :-1], -1, 0))
        return jnp.moveaxis(qs, 0, -1)                     # (..., d)

    qn = jax.vmap(lambda rr: deflate(a, rr), in_axes=-1, out_axes=-2)(lam)
    b = jnp.einsum("...n,...nk->...k", R, qn)              # (..., d)
    return a, b


def transfer_eval_fft(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                      L: int) -> jnp.ndarray:
    """Evaluate H on the L roots of unity in O~(L) (Lemma A.6).

    a: (..., d+1) monic denominator (descending powers of z); b: (..., d)
    numerator of z^-1..z^-d. In z^-1 form: den = 1 + a1 z^-1 + ...;
    num = b1 z^-1 + ... — zero-pad to L and FFT.
    """
    d = a.shape[-1] - 1
    batch = a.shape[:-1]
    den = jnp.zeros(batch + (L,), jnp.complex64).at[..., :d + 1].set(a)
    num = jnp.zeros(batch + (L,), jnp.complex64).at[..., 1:d + 1].set(b)
    Fd = jnp.fft.fft(den, axis=-1)
    Fn = jnp.fft.fft(num, axis=-1)
    return Fn / Fd + h0[..., None]


def impulse_from_tf(a, b, h0, L: int) -> jnp.ndarray:
    """Impulse response h[0..L-1] via inverse FFT of the frequency response.

    Note: this is the L-periodic (circular) impulse response; for stable
    systems the wrap-around error decays as rho(A)^L (App. A.4).
    """
    H = transfer_eval_fft(a, b, h0, L)
    return jnp.real(jnp.fft.ifft(H, axis=-1))


def get_tf_from_ss(A: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
                   h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """App. A.6 Listing 1: dense (A, B, C, h0) -> (a, b) coefficients.

    a = poly(eig(A)); b = poly(eig(A - B C)) + (h0 - 1) a, then the strictly
    proper numerator is recovered as beta_n = b_n - b_0 a_n with b_0 = h0.
    Returns (a (d+1,), beta (d,)).
    """
    eigA = jnp.linalg.eigvals(A)
    a = poly_from_roots(eigA)
    eigABC = jnp.linalg.eigvals(A - jnp.outer(B, C))
    b_full = poly_from_roots(eigABC) + (h0 - 1.0) * a      # simply-proper num
    beta = b_full[1:] - b_full[0] * a[1:]
    return a, beta


def companion_from_tf(a: jnp.ndarray, beta: jnp.ndarray, h0: jnp.ndarray):
    """App. A.5: companion canonical (A, B, C, h0) from (a, beta)."""
    d = beta.shape[-1]
    A = jnp.zeros((d, d), a.dtype)
    A = A.at[0, :].set(-a[1:])
    A = A.at[jnp.arange(1, d), jnp.arange(0, d - 1)].set(1.0)
    B = jnp.zeros((d,), a.dtype).at[0].set(1.0)
    C = beta
    return A, B, C, h0


def companion_step(x, u, alpha, beta, h0):
    """Lemma A.7 / Listing 2: O(d) companion recurrence.

    x: (..., d) state; u: (...,) input; alpha = a[1:], beta numerator.
    Returns (x', y).
    """
    y = jnp.einsum("...d,...d->...", beta, x) + h0 * u
    lr = u - jnp.einsum("...d,...d->...", alpha, x)
    x = jnp.roll(x, 1, axis=-1).at[..., 0].set(lr)
    return x, y
