"""Deterministic, seeded fault injection for the serving engine.

A `FaultInjector` holds a scripted schedule of `FaultEvent`s keyed by engine
tick. The engine polls the injector at fixed points in its step loop and the
injector replies with what to break this tick:

  * ``corrupt`` — poison one resident slot's cache row (NaN/Inf into the
    modal state, conv tail, or sequence/ring buffers) via
    `corrupt_cache_slot`. Exercises the state-integrity guards + quarantine
    path.
  * ``raise``   — make the next dispatch raise `FaultError` *before* the
    jitted call runs (so donated pool buffers stay valid on an injected
    fault; a genuine in-flight failure is handled separately by the
    engine's pool rebuild). Exercises dispatch-exception recovery.
  * ``stall``   — sleep the host loop for `duration_s`. Exercises the tick
    watchdog.
  * ``expire``  — force one resident request's deadline into the past.
    Exercises deadline eviction.
  * ``drift``   — silently scale one resident slot's serving state by
    (1 + value) via `drift_cache_slot`: the perturbation stays finite and
    inside the modal-norm bound, so it is invisible to the NaN/Inf and
    norm guards — only the drift sentinel's exact-path shadow decode
    detects it. Exercises the sentinel + epoch-demotion path.

Everything is deterministic: slot choice for events that don't pin one uses
a counter-seeded `np.random.default_rng`, never wall clock, so a schedule
replays identically run to run — the property the bit-exactness tests for
unaffected slots rely on.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

_KINDS = ("corrupt", "raise", "stall", "expire", "drift")
_WHERES = ("state", "conv", "seq", "any")

# leaf-name classification mirroring models.model._init_block_cache
_WHERE_KEYS = {
    "state": ("x_re", "x_im", "ssm", "h"),
    "conv": ("conv",),
    "seq": ("k", "v", "kv", "fut"),
}


class FaultError(RuntimeError):
    """Raised by the injector in place of a dispatch (kind="raise")."""


@dataclasses.dataclass
class FaultEvent:
    tick: int                   # engine tick index at which to fire
    kind: str                   # one of _KINDS
    where: str = "state"        # corrupt: leaf class (see _WHERE_KEYS)
    value: float = float("nan")  # corrupt: poison value (nan / +-inf / any)
    slot: int = -1              # target slot; -1 = seeded pick among residents
    duration_s: float = 0.0     # stall: host-loop sleep

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "corrupt" and self.where not in _WHERES:
            raise ValueError(f"unknown corrupt target {self.where!r}")

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultEvent":
        d = dict(d)
        v = d.get("value")
        if isinstance(v, str):          # JSON has no nan/inf literals
            d["value"] = float(v)
        return cls(**d)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if not math.isfinite(d["value"]):
            d["value"] = str(d["value"])
        return d


class FaultInjector:
    """Scripted schedule of faults + a log of what actually fired."""

    def __init__(self, events: Sequence[FaultEvent] = (), *, seed: int = 0):
        self.events = sorted((e if isinstance(e, FaultEvent)
                              else FaultEvent.from_dict(e) for e in events),
                             key=lambda e: e.tick)
        self.seed = int(seed)
        self.log: List[Dict[str, Any]] = []

    # -- (de)serialization -------------------------------------------------
    @classmethod
    def from_json(cls, text_or_path: str) -> "FaultInjector":
        text = text_or_path
        if not text.lstrip().startswith(("{", "[")):
            with open(text_or_path) as f:
                text = f.read()
        doc = json.loads(text)
        if isinstance(doc, list):
            doc = {"events": doc}
        return cls([FaultEvent.from_dict(d) for d in doc.get("events", [])],
                   seed=doc.get("seed", 0))

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "events": [e.to_dict() for e in self.events]})

    # -- schedule queries (engine-facing) ----------------------------------
    def _at(self, tick: int, kind: str) -> List[FaultEvent]:
        return [e for e in self.events if e.tick == tick and e.kind == kind]

    def corruptions(self, tick: int) -> List[FaultEvent]:
        return self._at(tick, "corrupt")

    def raise_if_scheduled(self, tick: int) -> None:
        for e in self._at(tick, "raise"):
            self.record(tick, "raise", slot=e.slot)
            raise FaultError(f"injected dispatch fault at tick {tick}")

    def stall_s(self, tick: int) -> float:
        total = sum(e.duration_s for e in self._at(tick, "stall"))
        if total:
            self.record(tick, "stall", duration_s=total)
        return total

    def expirations(self, tick: int) -> List[FaultEvent]:
        return self._at(tick, "expire")

    def drifts(self, tick: int) -> List[FaultEvent]:
        return self._at(tick, "drift")

    def pick_slot(self, event: FaultEvent, tick: int,
                  residents: Sequence[int]) -> Optional[int]:
        """Event's pinned slot if resident, else a seeded deterministic pick
        among residents; None when nothing is resident to fault."""
        if event.slot >= 0:
            return event.slot if event.slot in residents else None
        if not residents:
            return None
        rng = np.random.default_rng((self.seed << 20) ^ tick)
        return int(sorted(residents)[rng.integers(len(residents))])

    def record(self, tick: int, kind: str, **detail) -> None:
        self.log.append({"tick": tick, "kind": kind, **detail})

    @property
    def max_tick(self) -> int:
        return max((e.tick for e in self.events), default=-1)


def corrupt_cache_slot(cache, slot: int, where: str = "state",
                       value: float = float("nan")):
    """Poison slot `slot` of a raw pooled per-slot cache: set every element
    of the matching leaves' slot row to `value`. Group leaves carry a
    leading layer axis (batch axis 1); remainder leaves use axis 0. Only
    float leaves are touched. If `where` names a leaf class the cache kind
    doesn't have (e.g. "state" on an attention arch), falls back to "any"
    so one standard schedule exercises every cache kind."""
    keys = _WHERE_KEYS.get(where)      # None for "any"

    def match(k, v) -> bool:
        if not jnp.issubdtype(v.dtype, jnp.inexact):
            return False
        return keys is None or k in keys

    def has_match(c) -> bool:
        return any(match(k, v) for k, v in c.items())

    blocks = list(cache["groups"].values()) + list(cache.get("rem") or [])
    if keys is not None and not any(has_match(c) for c in blocks):
        keys = None                    # fall back to "any"

    def poison(c, batch_axis: int):
        out = dict(c)
        for k, v in c.items():
            if not match(k, v):
                continue
            if batch_axis == 1:
                out[k] = v.at[:, slot].set(value)
            else:
                out[k] = v.at[slot].set(value)
        return out

    out = {"groups": {lk: poison(lv, 1) for lk, lv in cache["groups"].items()},
           "pos": cache["pos"]}
    if "rem" in cache:
        out["rem"] = [poison(rc, 0) for rc in cache["rem"]]
    return out


def drift_cache_slot(cache, slot: int, eps: float = 0.05):
    """Silently perturb slot `slot`'s serving state: scale the recurrent
    state leaves (modal x_re/x_im, SSM/RG-LRU state) — or, on cache kinds
    without one, the conv tail — by (1 + eps). Unlike `corrupt_cache_slot`
    the result stays finite and, for moderate eps, inside the modal-norm
    bound, so the NaN/Inf and norm guards never fire; only the drift
    sentinel's exact-path shadow decode can tell the slot has gone wrong.
    Same axis conventions as `corrupt_cache_slot`."""
    if not math.isfinite(eps):
        eps = 0.05                   # FaultEvent.value defaults to nan
    targets = _WHERE_KEYS["state"]
    blocks = list(cache["groups"].values()) + list(cache.get("rem") or [])
    if not any(k in c for c in blocks for k in targets):
        targets = _WHERE_KEYS["conv"]    # exact kinds: skew the short conv

    def scale(c, batch_axis: int):
        out = dict(c)
        for k, v in c.items():
            if k not in targets or not jnp.issubdtype(v.dtype, jnp.inexact):
                continue
            if batch_axis == 1:
                out[k] = v.at[:, slot].multiply(1.0 + eps)
            else:
                out[k] = v.at[slot].multiply(1.0 + eps)
        return out

    out = {"groups": {lk: scale(lv, 1) for lk, lv in cache["groups"].items()},
           "pos": cache["pos"]}
    if "rem" in cache:
        out["rem"] = [scale(rc, 0) for rc in cache["rem"]]
    return out
