"""Process-wide toggles.

DRYRUN_UNROLL: XLA's cost_analysis counts a while-loop body ONCE regardless of
trip count, which would silently undercount FLOPs/bytes of scanned layer
stacks and chunked-attention loops in the roofline. The dry-run sets this flag
to fully unroll structural scans (layer groups, attention kv blocks, SSD
chunks) so the compiled module's cost analysis reflects a real step. Normal
execution keeps scans rolled (compile-time friendly).
"""
DRYRUN_UNROLL = False


def set_dryrun_unroll(v: bool) -> None:
    global DRYRUN_UNROLL
    DRYRUN_UNROLL = v


def scan_unroll(length: int) -> int:
    return length if DRYRUN_UNROLL else 1
