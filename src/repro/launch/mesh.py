"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import to
materialize the placeholder devices.

Topology (TPU v5e-256 pods):
  single pod : (data=16, model=16)            = 256 chips
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over actually-present devices (tests / smoke runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_slot_mesh(n_shards: int | None = None):
    """1-D data mesh for the serving slot pool, over the first `n_shards`
    local devices (default: all of them). Built directly as a Mesh — unlike
    jax.make_mesh this accepts a device subset, so a 4-way pool can run on
    4 of 8 forced host devices."""
    import numpy as np
    devices = jax.devices()
    n = len(devices) if n_shards is None else int(n_shards)
    if n < 1 or n > len(devices):
        raise ValueError(f"need {n} devices, have {len(devices)}")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:n]), ("data",))


HW = {
    # TPU v5e per-chip constants used for the roofline terms
    "peak_flops_bf16": 197e12,      # FLOP/s
    "hbm_bw": 819e9,                # B/s
    "ici_bw": 50e9,                 # B/s per link
    "hbm_bytes": 16e9,
}
