"""Span-based request-lifecycle tracer for the serving engine.

The scheduler's host loop emits *phase* spans every tick (dispatch, retire,
admit, deadline sweep, fault application) and *request* spans at each
request's terminal transition (queue -> prefill -> decode -> retire), built
from the engine's own recorded timestamps so the exported trace reconstructs
a request's measured TTFT and end-to-end latency exactly. Recovery events
(quarantine, re-prefill, engine demotion, ...) land as instant events on the
affected request's track, so a faulted request's timeline shows *why* it was
slow.

Design constraints (the observability overhead gate in
benchmarks/check_regression.py holds tracing + metrics to <= 2% of
saturated-decode throughput, with zero steady-state compiles):

  * everything is host-side Python — no device work, no jit, no recompiles;
  * recording one span costs two clock reads and one deque append; events
    are compact tuples until export;
  * the event store is a bounded ring (``capacity`` events, oldest dropped,
    drops counted) so a long-running serve cannot grow without limit;
  * the disabled path is ``NULL_TRACER`` — a singleton whose methods are
    no-ops and whose ``span``/``device_span`` return one shared null context
    manager, so instrumented code pays ~an attribute lookup when tracing is
    off.

``device_span`` additionally enters ``jax.profiler.TraceAnnotation``, so a
``jax.profiler.trace()`` / TensorBoard capture of the same run carries the
scheduler's phase names alongside the XLA ops.

Export is Chrome-trace JSON (``to_chrome_trace()`` / ``save(path)``): open
the file in Perfetto (https://ui.perfetto.dev) or chrome://tracing. The host
loop renders as pid 0 / tid 0; each request renders as its own track (pid 1,
tid = rid).
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

try:  # pragma: no cover - availability depends on the jax build
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover
    _TraceAnnotation = None

# event tuples: (ph, name, cat, pid, tid, t0, dur, args)
#   ph "X" = complete span (dur in seconds), "i" = instant (dur ignored)
HOST_PID = 0        # host-loop phase spans
REQUEST_PID = 1     # per-request lifecycle tracks (tid = rid)


class _NullContext:
    """Shared no-op context manager for the disabled tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullContext()


class _Span:
    """Context manager recording one complete ("X") host-phase span."""

    __slots__ = ("_tr", "_name", "_cat", "_args", "_t0")

    def __init__(self, tr: "Tracer", name: str, cat: str, args):
        self._tr = tr
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = self._tr._clock()
        return self

    def __exit__(self, *exc):
        tr = self._tr
        tr._emit(("X", self._name, self._cat, HOST_PID, 0, self._t0,
                  tr._clock() - self._t0, self._args))
        return False


class _DeviceSpan(_Span):
    """A host span that also enters a jax.profiler.TraceAnnotation, so a
    concurrent profiler capture carries the scheduler phase names."""

    __slots__ = ("_ann",)

    def __enter__(self):
        if _TraceAnnotation is not None:
            self._ann = _TraceAnnotation(self._name)
            self._ann.__enter__()
        else:  # pragma: no cover
            self._ann = None
        return super().__enter__()

    def __exit__(self, *exc):
        if self._ann is not None:
            self._ann.__exit__(*exc)
        return super().__exit__(*exc)


class Tracer:
    """Bounded in-memory trace recorder (see module docstring).

    `clock` must match the engine's clock (both default to time.monotonic)
    so span timestamps and the engine's request timestamps share one
    timebase.
    """

    enabled = True

    def __init__(self, capacity: int = 65536,
                 clock: Callable[[], float] = time.monotonic):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self._clock = clock
        self._events: deque = deque(maxlen=int(capacity))
        self._epoch = clock()
        self.total = 0          # events ever emitted (ring drops the oldest)
        self.dropped = 0

    # -- recording -----------------------------------------------------
    def _emit(self, ev: Tuple) -> None:
        if len(self._events) == self._events.maxlen:
            self.dropped += 1
        self._events.append(ev)
        self.total += 1

    def span(self, name: str, cat: str = "phase", **args):
        """Host-phase span context manager (pid 0 / tid 0)."""
        return _Span(self, name, cat, args or None)

    def device_span(self, name: str, cat: str = "device", **args):
        """Span around a device dispatch: host span + jax.profiler
        TraceAnnotation. Note the host duration measures *enqueue* time —
        JAX dispatch is async, so the device work itself shows up in a
        profiler capture, not in this span's dur."""
        return _DeviceSpan(self, name, cat, args or None)

    def complete(self, name: str, t0: float, t1: float, *,
                 cat: str = "request", rid: Optional[int] = None,
                 **args) -> None:
        """Record a span from already-measured timestamps (the scheduler
        uses the Request's own t_submit/t_admitted/... so the trace agrees
        exactly with the measured TTFT/latency)."""
        pid, tid = (REQUEST_PID, rid) if rid is not None else (HOST_PID, 0)
        self._emit(("X", name, cat, pid, tid, t0, max(t1 - t0, 0.0),
                    args or None))

    def instant(self, name: str, *, cat: str = "event",
                rid: Optional[int] = None, ts: Optional[float] = None,
                **args) -> None:
        pid, tid = (REQUEST_PID, rid) if rid is not None else (HOST_PID, 0)
        t = self._clock() if ts is None else ts
        self._emit(("i", name, cat, pid, tid, t, 0.0, args or None))

    # -- inspection / export -------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> List[Dict[str, Any]]:
        """Decoded events (dicts with seconds-based timestamps), oldest
        first. For tests and ad-hoc inspection; export uses Chrome JSON."""
        out = []
        for ph, name, cat, pid, tid, t0, dur, args in self._events:
            out.append({"ph": ph, "name": name, "cat": cat, "pid": pid,
                        "tid": tid, "ts": t0, "dur": dur,
                        "args": dict(args) if args else {}})
        return out

    def request_timeline(self, rid: int) -> List[Dict[str, Any]]:
        """All events on one request's track, ordered by timestamp."""
        evs = [e for e in self.events()
               if e["pid"] == REQUEST_PID and e["tid"] == rid]
        return sorted(evs, key=lambda e: (e["ts"], e["ts"] + e["dur"]))

    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome-trace/Perfetto JSON object (timestamps in µs relative to
        the tracer's epoch)."""
        evs: List[Dict[str, Any]] = [
            {"ph": "M", "name": "process_name", "pid": HOST_PID, "tid": 0,
             "args": {"name": "serve host loop"}},
            {"ph": "M", "name": "process_name", "pid": REQUEST_PID, "tid": 0,
             "args": {"name": "requests"}},
        ]
        named_reqs = set()
        for ph, name, cat, pid, tid, t0, dur, args in self._events:
            if pid == REQUEST_PID and tid not in named_reqs:
                named_reqs.add(tid)
                evs.append({"ph": "M", "name": "thread_name",
                            "pid": pid, "tid": tid,
                            "args": {"name": f"request {tid}"}})
            ev: Dict[str, Any] = {
                "ph": ph, "name": name, "cat": cat, "pid": pid, "tid": tid,
                "ts": (t0 - self._epoch) * 1e6,
            }
            if ph == "X":
                ev["dur"] = dur * 1e6
            else:
                ev["s"] = "t"
            if args:
                ev["args"] = dict(args)
            evs.append(ev)
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "total_events": self.total}}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


class NullTracer:
    """Disabled tracer: same surface as Tracer, near-zero cost."""

    enabled = False
    total = 0
    dropped = 0

    def span(self, name, cat="phase", **args):
        return _NULL_CTX

    def device_span(self, name, cat="device", **args):
        return _NULL_CTX

    def complete(self, name, t0, t1, *, cat="request", rid=None, **args):
        pass

    def instant(self, name, *, cat="event", rid=None, ts=None, **args):
        pass

    def __len__(self) -> int:
        return 0

    def events(self):
        return []

    def request_timeline(self, rid):
        return []

    def to_chrome_trace(self):
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


NULL_TRACER = NullTracer()
