"""Fault-tolerant training loop.

Features required for 1000+-node operation, implemented host-side:
  * checkpoint/restart  — periodic async checkpoints; on startup the loop
    restores the newest complete checkpoint and resumes at that step. The
    data pipeline is step-indexed, so resumption is exact.
  * preemption handling — SIGTERM/SIGINT trigger a final synchronous
    checkpoint before exit (the TPU-pod eviction pattern).
  * failure injection   — `fail_at_step` simulates a crash (tests restart).
  * straggler watchdog  — per-step wall times are tracked; steps slower than
    `straggler_factor` x the running median are counted and logged. On a real
    fleet this signal feeds the scheduler to hot-swap the slow host; here it
    is surfaced in metrics.
  * elastic data scaling — the loop consumes `global_batch` from the source;
    on restart with a different mesh size, the same step indexing keeps the
    token order deterministic (batch -> token mapping is step-major).
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.train.checkpoint import Checkpointer


class StragglerWatchdog:
    def __init__(self, factor: float = 2.0, window: int = 50):
        self.factor = factor
        self.times = []
        self.window = window
        self.count = 0

    def observe(self, dt: float) -> bool:
        slow = False
        if len(self.times) >= 5:
            med = float(np.median(self.times[-self.window:]))
            slow = dt > self.factor * med
            self.count += int(slow)
        self.times.append(dt)
        return slow


def train(train_step: Callable, params, opt_state, batches: Iterator[Dict],
          *, steps: int, ckpt: Optional[Checkpointer] = None,
          ckpt_every: int = 100, log_every: int = 10,
          fail_at_step: Optional[int] = None,
          hooks: Optional[Dict[str, Callable]] = None) -> Dict:
    """Run `steps` optimizer steps with checkpoint/restart semantics.

    Returns {'params', 'opt_state', 'step', 'metrics', 'straggler_count'}.
    """
    start_step = 0
    if ckpt is not None:
        (params, opt_state), restored = ckpt.restore((params, opt_state))
        if restored is not None:
            start_step = restored + 1
            print(f"[train] restored checkpoint at step {restored}; "
                  f"resuming from {start_step}", flush=True)

    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True

    old_term = signal.signal(signal.SIGTERM, _handler)
    watchdog = StragglerWatchdog()
    metrics = {}
    step = start_step - 1
    try:
        for step in range(start_step, steps):
            batch = next(batches)
            t0 = time.time()
            params, opt_state, metrics = train_step(
                params, opt_state, batch, jax.numpy.asarray(step))
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            slow = watchdog.observe(dt)
            if hooks and "on_step" in hooks:
                hooks["on_step"](step, metrics)
            if step % log_every == 0:
                print(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                      f"dt={dt*1e3:.0f}ms{' STRAGGLER' if slow else ''}",
                      flush=True)
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            if ckpt is not None and step > 0 and step % ckpt_every == 0:
                ckpt.save(step, (params, opt_state), blocking=False)
            if preempted["flag"]:
                print("[train] preemption signal: checkpoint + exit", flush=True)
                break
    finally:
        if ckpt is not None:
            ckpt.wait()
            if step >= start_step:
                ckpt.save(step, (params, opt_state), blocking=True)
        signal.signal(signal.SIGTERM, old_term)
    return {"params": params, "opt_state": opt_state, "step": step,
            "metrics": metrics, "straggler_count": watchdog.count}
