"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

Hybrid: 38L d_model=4096, pattern = (RG-LRU, RG-LRU, local-attn) repeating
(1 attention : 2 recurrent), 16H local attention with kv=1 (MQA),
d_ff=12288 GeGLU, vocab=256000, window=2048.
Sub-quadratic: runs the long_500k decode cell.
"""
from repro.configs.base import LOCAL_ATTN, RGLRU, ModelConfig, RGLRUConfig, register


@register
def recurrentgemma_9b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab=256000,
        act="geglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        pattern=(RGLRU, RGLRU, LOCAL_ATTN),
        window=2048,
        rglru=RGLRUConfig(d_conv=4, expand=1, window=2048),
        tie_embeddings=True,
        max_seq=1_048_576,
    )
