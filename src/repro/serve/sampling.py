"""Token sampling: greedy, temperature, top-k, top-p (nucleus).

`sample_token` takes python-scalar params shared across the batch (one
request replicated, or homogeneous batches). `sample_token_slots` takes
per-row (B,) parameter vectors — the continuous-batching engine serves
requests with heterogeneous sampling params in one batched step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(key, logits, *, temperature: float = 1.0, top_k: int = 0,
                 top_p: float = 1.0):
    """logits: (B, V) -> (B,) int32. One pipeline: scalar params broadcast
    into the per-slot implementation so the two paths can never diverge."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    B = logits.shape[0]
    return sample_token_slots(
        key, logits,
        temperature=jnp.full((B,), temperature, jnp.float32),
        top_k=jnp.full((B,), top_k, jnp.int32),
        top_p=jnp.full((B,), top_p, jnp.float32))


def sample_token_slots(key, logits, *, temperature, top_k, top_p):
    """Per-slot sampling. logits: (B, V); temperature/top_k/top_p: (B,).

    Rows with temperature <= 0 are greedy; top_k <= 0 / top_p >= 1 disable
    the respective filter for that row. Each row draws from its own PRNG
    stream (split of `key`) so one slot's draw never perturbs another's.
    """
    B, V = logits.shape
    temperature = jnp.asarray(temperature, jnp.float32)
    top_k = jnp.asarray(top_k, jnp.int32)
    top_p = jnp.asarray(top_p, jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    lg = logits.astype(jnp.float32) / jnp.clip(temperature, 1e-6)[:, None]
    # per-row top-k: the k-th largest value is the row's cutoff (k<=0 -> V)
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V)
    srt = jnp.sort(lg, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(srt, (k_eff - 1)[:, None], axis=-1)
    lg = jnp.where(lg < kth, -jnp.inf, lg)
    # per-row top-p over the filtered logits (mirrors sample_token)
    srt2 = jnp.sort(lg, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(srt2, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.sum(cum < top_p[:, None], axis=-1)
    cutoff = jnp.take_along_axis(srt2, jnp.clip(cutoff_idx, 0, V - 1)[:, None],
                                 axis=-1)
    lg = jnp.where((top_p[:, None] < 1.0) & (lg < cutoff), -jnp.inf, lg)

    keys = jax.random.split(key, B)
    sampled = jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)
