from repro.serve.engine import GenerationEngine                    # noqa: F401
from repro.serve.sampling import sample_token, sample_token_slots  # noqa: F401
from repro.serve.scheduler import (ContinuousBatchingEngine,       # noqa: F401
                                   Request, SamplingParams,
                                   run_request_stream,
                                   synthesize_request_stream)
