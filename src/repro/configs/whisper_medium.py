"""Whisper-medium [arXiv:2212.04356].

Encoder-decoder: 24L decoder + 24L encoder, d_model=1024 16H (MHA)
d_ff=4096 vocab=51865. The conv audio frontend is a STUB: input_specs()
provides precomputed frame embeddings (1500 frames) for the encoder.
Whisper uses learned absolute positions; we keep RoPE off by using
theta=0 sentinel handled in the model (absolute embeddings).
"""
from repro.configs.base import ATTN, ModelConfig, register


@register
def whisper_medium() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab=51865,
        act="gelu",
        norm="layernorm",
        rope_theta=0.0,          # sentinel: learned absolute positions
        pattern=(ATTN,),
        enc_dec=True,
        n_enc_layers=24,
        frontend="audio_stub",
        frontend_len=1500,
        tie_embeddings=True,
        max_seq=32768,
    )
