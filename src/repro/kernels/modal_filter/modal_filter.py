"""Pallas TPU kernel: blockwise modal-filter materialization.

Grid: (C // cb, L // lb). Each program holds a (cb, d) parameter tile and
produces a (cb, lb) output tile. The Vandermonde basis a^(t-1) e^{i th (t-1)}
for the block's time range is generated in VMEM/VREGs (exp/cos/sin on the
VPU) and contracted over the mode axis.

TPU adaptation notes: time is the lane (128) axis and channels the sublane
axis, so lb is a multiple of 128 and cb a multiple of 8; powers are computed
as exp(t * log a) rather than iterated multiplication, which keeps every
block independent (no cross-block carries -> embarrassingly parallel grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(log_a_ref, theta_ref, R_re_ref, R_im_ref, h0_ref, out_ref, *,
            lb: int):
    li = pl.program_id(1)
    # time indices for this block, as exponents t-1 (output index t)
    t = (li * lb + jax.lax.iota(jnp.float32, lb)) - 1.0     # (lb,)
    log_a = log_a_ref[...]                                  # (cb, d)
    theta = theta_ref[...]
    mag = jnp.exp(log_a[:, :, None] * t[None, None, :])     # (cb, d, lb)
    ang = theta[:, :, None] * t[None, None, :]
    basis = mag * jnp.cos(ang) * R_re_ref[...][:, :, None] \
        - mag * jnp.sin(ang) * R_im_ref[...][:, :, None]
    h = jnp.sum(basis, axis=1)                              # (cb, lb)
    # t == 0 lane (only in block li == 0) is the passthrough h0
    is_t0 = (t[None, :] == -1.0)
    out_ref[...] = jnp.where(is_t0, h0_ref[...][:, None], h)


@functools.partial(jax.jit, static_argnames=("L", "cb", "lb", "interpret"))
def modal_filter_pallas(log_a, theta, R_re, R_im, h0, *, L: int,
                        cb: int = 8, lb: int = 512, interpret: bool = True):
    C, d = log_a.shape
    assert L % lb == 0 and C % cb == 0, (C, L, cb, lb)
    grid = (C // cb, L // lb)
    param_spec = pl.BlockSpec((cb, d), lambda ci, li: (ci, 0))
    return pl.pallas_call(
        functools.partial(_kernel, lb=lb),
        grid=grid,
        in_specs=[param_spec, param_spec, param_spec, param_spec,
                  pl.BlockSpec((cb,), lambda ci, li: (ci,))],
        out_specs=pl.BlockSpec((cb, lb), lambda ci, li: (ci, li)),
        out_shape=jax.ShapeDtypeStruct((C, L), jnp.float32),
        interpret=interpret,
    )(log_a.astype(jnp.float32), theta.astype(jnp.float32),
      R_re.astype(jnp.float32), R_im.astype(jnp.float32),
      h0.astype(jnp.float32))
