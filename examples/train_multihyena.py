"""End-to-end training driver: pretrain a MultiHyena LM with the full
production substrate — sharded data pipeline, AdamW + cosine, checkpointing,
preemption-safe restart, straggler watchdog.

Full deliverable setting (paper Sec. 5.1-style run, scaled to this host):

  PYTHONPATH=src python examples/train_multihyena.py \
      --d-model 512 --layers 12 --steps 300 --batch 8 --seq 512

That instantiates a ~45M-param MultiHyena (8 heads). On a real v5e pod the
same driver launches via repro.launch.train with the production mesh. A
--tiny flag runs a 2-minute CPU version.
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax

from repro.configs.base import HYENA, HyenaConfig, ModelConfig
from repro.data.pipeline import SyntheticLM, make_batches
from repro.distributed.sharding import unzip
from repro.models.model import init_params
from repro.train.checkpoint import Checkpointer
from repro.train.loop import train
from repro.train.train_step import init_opt, make_train_step


def build_cfg(d_model, layers, vocab):
    return ModelConfig(
        name=f"multihyena-{d_model}x{layers}", family="lcsm",
        n_layers=layers, d_model=d_model, n_heads=8, n_kv_heads=8,
        head_dim=d_model // 8, d_ff=4 * d_model, vocab=vocab, act="gelu",
        norm="layernorm", pattern=(HYENA,),
        hyena=HyenaConfig(n_filter_heads=8, filter_order=64, filter_emb=33),
        tie_embeddings=True, max_seq=65536, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", type=str, default="/tmp/multihyena_run")
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()
    if args.tiny:
        args.d_model, args.layers, args.vocab = 128, 4, 512
        args.steps, args.seq = 60, 128

    cfg = build_cfg(args.d_model, args.layers, args.vocab)
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  {n/1e6:.1f}M params")
    opt = init_opt(params)
    src = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=0)
    step_fn = jax.jit(make_train_step(cfg, None, base_lr=args.lr,
                                      warmup=args.steps // 10,
                                      total_steps=args.steps, remat="none"))
    ck = Checkpointer(args.ckpt, keep=2)
    start = (ck.latest_step() + 1) if ck.latest_step() is not None else 0
    out = train(step_fn, params, opt, make_batches(src, start_step=start),
                steps=args.steps, ckpt=ck, ckpt_every=50, log_every=10)
    print(f"done at step {out['step']}: loss {float(out['metrics']['loss']):.4f} "
          f"(stragglers flagged: {out['straggler_count']})")
    print(f"checkpoints: {ck.all_steps()} in {args.ckpt}")


if __name__ == "__main__":
    main()
