"""Process-wide toggles.

DRYRUN_UNROLL: XLA's cost_analysis counts a while-loop body ONCE regardless of
trip count, which would silently undercount FLOPs/bytes of scanned layer
stacks and chunked-attention loops in the roofline. The dry-run sets this flag
to fully unroll structural scans (layer groups, attention kv blocks, SSD
chunks) so the compiled module's cost analysis reflects a real step. Normal
execution keeps scans rolled (compile-time friendly).
"""
DRYRUN_UNROLL = False


def set_dryrun_unroll(v: bool) -> None:
    global DRYRUN_UNROLL
    DRYRUN_UNROLL = v


def scan_unroll(length: int) -> int:
    return length if DRYRUN_UNROLL else 1


# Serving decode is latency-critical and its layer-group scans are small
# (the pattern period, not n_layers): scanning over stacked params makes XLA
# dynamic-slice every leaf per iteration, which measures ~2x the whole step
# cost at serving widths on CPU. Decode paths unroll up to this many groups;
# training/prefill keep scans rolled (HLO size / compile-time friendly).
DECODE_UNROLL_MAX = 8


def decode_unroll(length: int) -> int:
    return length if (DRYRUN_UNROLL or length <= DECODE_UNROLL_MAX) else 1
