"""Resilience layer: seeded fault injection, state-integrity guards,
quarantine + exact re-prefill recovery, graceful degradation, and engine
checkpoint/restore.

The load-bearing contract: under a scripted fault schedule the engine
completes EVERY submitted request with a terminal status (zero crashes),
poisoned requests finish with ERROR after bounded retries, and requests
whose slots were never faulted produce greedy outputs token-identical to a
fault-free run (the recovered request itself may diverge by one float-path:
re-prefill vs step-by-step decode are equal only to numerical tolerance).
"""
import json
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ATTN, HYENA, HyenaConfig, ModelConfig
from repro.distributed.sharding import unzip
from repro.models.model import init_cache, modal_state_bound, slot_health
from repro.serve.checkpoint import restore_engine, save_engine
from repro.serve.engine import GenerationEngine
from repro.serve.faults import (FaultEvent, FaultInjector, corrupt_cache_slot)
from repro.serve.metrics import ResilienceCounters, count_compiles
from repro.serve.sampling import sample_token_slots
from repro.serve.scheduler import (ContinuousBatchingEngine, Request,
                                   SamplingParams)

MAX_LEN = 48
PROMPT_LENS = (4, 7, 12, 20, 9)
GEN_LENS = (8, 5, 11, 6, 9)


def _hyena_cfg():
    return ModelConfig(name="res-hyena", family="lcsm", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, vocab=64, act="gelu", norm="layernorm",
                       pattern=(HYENA,),
                       hyena=HyenaConfig(n_filter_heads=2, filter_order=16,
                                         filter_emb=9, distill_order=8),
                       max_seq=512, dtype="float32")


def _attn_cfg():
    return ModelConfig(name="res-attn", family="dense", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, vocab=64, act="gelu", norm="layernorm",
                       pattern=(ATTN,), max_seq=512, dtype="float32")


@pytest.fixture(scope="module")
def hyena_model():
    cfg = _hyena_cfg()
    params, _ = unzip(init_params_seeded(cfg))
    return cfg, params


@pytest.fixture(scope="module")
def attn_model():
    cfg = _attn_cfg()
    params, _ = unzip(init_params_seeded(cfg))
    return cfg, params


def init_params_seeded(cfg):
    from repro.models.model import init_params
    return init_params(jax.random.PRNGKey(0), cfg)


def _prompts(vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32)
            for n in PROMPT_LENS]


_SEQ_CACHE = {}


def _sequential_greedy(cfg, params, mode):
    """Fault-free per-request baseline (cached per module run)."""
    key = (cfg.name, mode)
    if key not in _SEQ_CACHE:
        eng = GenerationEngine(params, cfg, max_len=MAX_LEN, mode=mode)
        prompts = _prompts(cfg.vocab)
        _SEQ_CACHE[key] = [
            np.asarray(eng.generate(jax.random.PRNGKey(1),
                                    jnp.asarray(p)[None], g)[0][0])
            for p, g in zip(prompts, GEN_LENS)]
    return _SEQ_CACHE[key]


def _affected_rids(eng):
    """Requests a fault actually touched (quarantined, expired, rejected,
    poisoned, or recovered through a pool rebuild / engine demotion — the
    latter two requeue every resident, so treat every request seen at the
    event's tick as affected)."""
    rids = {ev["rid"] for ev in eng.events if "rid" in ev}
    if any(ev["kind"] in ("pool_rebuild", "engine_demotion")
           for ev in eng.events):
        rids |= {r.rid for r in eng.finished}
    return rids


def _check_unaffected_exact(eng, want):
    """Every request reached a terminal status; fault-untouched requests are
    token-identical to the fault-free baseline."""
    by_rid = {r.rid: r for r in eng.finished}
    assert sorted(by_rid) == list(range(len(want)))
    affected = _affected_rids(eng)
    assert len(affected) < len(want), "schedule faulted every request"
    for rid, w in enumerate(want):
        r = by_rid[rid]
        assert r.status in ("finished", "error")
        if rid not in affected:
            assert r.status == "finished"
            np.testing.assert_array_equal(np.asarray(r.tokens), w)


def _run_with_faults(cfg, params, mode, events, *, n_slots=2, spec_k=0,
                     **kw):
    inj = FaultInjector(events, seed=0)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=n_slots,
                                   max_len=MAX_LEN, mode=mode,
                                   spec_k=spec_k, fault_injector=inj, **kw)
    for p, g in zip(_prompts(cfg.vocab), GEN_LENS):
        eng.submit(p, max_new_tokens=g)
    eng.run()
    return eng, inj


# ---------------------------------------------------------------------------
# fault injection + quarantine recovery, per cache kind
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode,where", [("distilled", "state"),
                                        ("cached_conv", "conv"),
                                        ("cached_conv", "any")])
def test_corruption_recovers_lcsm(hyena_model, mode, where):
    """NaN/Inf injected into a resident slot's cache row mid-decode trips
    the health guard; the slot is quarantined and its request re-prefilled
    from committed tokens. Untouched requests stay bit-identical, all
    requests complete, zero crashes."""
    cfg, params = hyena_model
    want = _sequential_greedy(cfg, params, mode)
    value = float("inf") if where == "conv" else float("nan")
    eng, inj = _run_with_faults(
        cfg, params, mode,
        [{"tick": 4, "kind": "corrupt", "where": where, "value": value}])
    assert [e for e in inj.log if e["kind"] == "corrupt"]
    assert eng.resilience.get("health_failures") >= 1
    assert eng.resilience.get("slot_reprefills") >= 1
    _check_unaffected_exact(eng, want)


def test_corruption_recovers_attention(attn_model):
    """Attention-KV pool: "state" has no modal leaves so the injector falls
    back to poisoning any float leaf (the kv ring). The NaN propagates into
    the logits, the fused logits-finiteness check catches it."""
    cfg, params = attn_model
    want = _sequential_greedy(cfg, params, "distilled")
    eng, inj = _run_with_faults(
        cfg, params, "distilled",
        [{"tick": 4, "kind": "corrupt", "where": "state", "value": "nan"}])
    assert [e for e in inj.log if e["kind"] == "corrupt"]
    assert eng.resilience.get("health_failures") >= 1
    _check_unaffected_exact(eng, want)


def test_fault_mid_speculation(hyena_model):
    """Corruption + an injected dispatch fault while the engine is running
    speculative rounds: the state-only guard quarantines the slot, the
    FaultError tick is skipped without invalidating the pool, and untouched
    requests remain identical to the fault-free spec run (which is itself
    greedy-identical to sequential decode)."""
    cfg, params = hyena_model
    want = _sequential_greedy(cfg, params, "distilled")
    eng, inj = _run_with_faults(
        cfg, params, "distilled",
        [{"tick": 4, "kind": "corrupt", "where": "state", "value": "nan"},
         {"tick": 8, "kind": "raise"}],
        spec_k=2)
    assert eng.resilience.get("health_failures") >= 1
    assert eng.resilience.get("dispatch_faults") == 1
    _check_unaffected_exact(eng, want)


def test_poisoned_after_bounded_retries(hyena_model):
    """A slot corrupted on every tick exhausts max_retries and its request
    completes with ERROR status ("poisoned") — it never wedges the engine —
    while other requests finish normally."""
    cfg, params = hyena_model
    want = _sequential_greedy(cfg, params, "distilled")
    events = [{"tick": t, "kind": "corrupt", "where": "state", "slot": 0}
              for t in range(3, 60)]
    eng, _ = _run_with_faults(cfg, params, "distilled", events,
                              max_retries=1, retry_backoff_ticks=0)
    poisoned = [r for r in eng.finished if r.finish_reason == "poisoned"]
    assert poisoned and all(r.status == "error" for r in poisoned)
    assert eng.resilience.get("poisoned") == len(poisoned)
    ok = [r for r in eng.finished if r.status == "finished"]
    assert len(ok) + len(poisoned) == len(want)
    for r in ok:
        if r.rid not in _affected_rids(eng):
            np.testing.assert_array_equal(np.asarray(r.tokens), want[r.rid])


def test_spec_demotion_after_repeated_quarantine(hyena_model):
    """Two quarantines of the same request demote it from speculation to
    plain decode (demote_spec_after default 2); it still completes. A
    single long request in a 1-slot pool pins both corruptions to it."""
    cfg, params = hyena_model
    inj = FaultInjector(
        [{"tick": 4, "kind": "corrupt", "where": "state", "slot": 0},
         {"tick": 10, "kind": "corrupt", "where": "state", "slot": 0}],
        seed=0)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=MAX_LEN,
                                   mode="distilled", spec_k=2,
                                   fault_injector=inj, max_retries=5)
    req = eng.submit(_prompts(cfg.vocab)[0], max_new_tokens=30)
    eng.run()
    assert req.retries == 2 and req.spec is False
    assert eng.resilience.get("spec_demotions") == 1
    assert req.status == "finished" and len(req.tokens) == 30


def test_engine_demotion_to_cached_conv(hyena_model):
    """Repeated distilled-path corruption (opt-in demote_engine_after)
    demotes the whole engine to the exact cached-conv kind; every request
    still reaches a terminal status and new decode runs conv-exact."""
    cfg, params = hyena_model
    eng, _ = _run_with_faults(
        cfg, params, "distilled",
        [{"tick": 4, "kind": "corrupt", "where": "state", "slot": 0},
         {"tick": 10, "kind": "corrupt", "where": "state", "slot": 0}],
        max_retries=5, demote_engine_after=2)
    assert eng.mode == "cached_conv" and eng._cache_kind == "conv"
    assert eng.resilience.get("engine_demotions") == 1
    assert len(eng.finished) == len(GEN_LENS)
    assert all(r.status in ("finished", "error") for r in eng.finished)


# ---------------------------------------------------------------------------
# deadlines, bounded queue, watchdog
# ---------------------------------------------------------------------------
def test_deadline_expiry_during_chunked_prefill(hyena_model):
    """A request whose deadline expires while its prompt is mid-chunked-
    prefill is cancelled (ERROR "deadline"), its reserved slot is freed, and
    the remaining requests complete bit-exactly."""
    cfg, params = hyena_model
    want = _sequential_greedy(cfg, params, "distilled")
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode="distilled", prefill_chunk=8)
    doomed = Request(rid=100, prompt=_prompts(cfg.vocab, seed=3)[3],
                     max_new_tokens=6, sampling=SamplingParams(),
                     deadline_s=0.0)
    eng.submit_request(doomed)
    for p, g in zip(_prompts(cfg.vocab), GEN_LENS):
        eng.submit(p, max_new_tokens=g)
    eng.run()
    assert doomed.status == "error" and doomed.finish_reason == "deadline"
    assert eng.resilience.get("deadline_expiries") >= 1
    by_rid = {r.rid: r for r in eng.finished}
    for rid, w in enumerate(want):
        assert by_rid[rid].status == "finished"
        np.testing.assert_array_equal(np.asarray(by_rid[rid].tokens), w)


def test_bounded_queue_rejection(hyena_model):
    """Admission control: submissions past max_queue complete immediately
    with ERROR "rejected" instead of growing the queue; accepted requests
    are unaffected and bit-exact."""
    cfg, params = hyena_model
    want = _sequential_greedy(cfg, params, "distilled")
    eng = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=MAX_LEN,
                                   mode="distilled", max_queue=2)
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(_prompts(cfg.vocab), GEN_LENS)]
    rejected = [r for r in reqs if r.finish_reason == "rejected"]
    accepted = [r for r in reqs if r.finish_reason != "rejected"]
    assert len(rejected) == 3 and len(accepted) == 2
    assert all(r.status == "error" for r in rejected)
    assert eng.resilience.get("rejected") == 3
    eng.run()
    for r in accepted:
        assert r.status == "finished"
        np.testing.assert_array_equal(np.asarray(r.tokens), want[r.rid])
    assert len(eng.finished) == len(reqs)  # rejections count as completions


def test_stall_trips_watchdog(hyena_model):
    """An injected host-loop stall exceeds the tick watchdog; the trip is
    counted and decode output is unaffected (determinism is positional, not
    timing-dependent)."""
    cfg, params = hyena_model
    want = _sequential_greedy(cfg, params, "distilled")
    eng, inj = _run_with_faults(
        cfg, params, "distilled",
        [{"tick": 3, "kind": "stall", "duration_s": 0.03}],
        watchdog_s=0.01)
    assert eng.resilience.get("watchdog_trips") >= 1
    assert [e for e in inj.log if e["kind"] == "stall"]
    by_rid = {r.rid: r for r in eng.finished}
    for rid, w in enumerate(want):
        np.testing.assert_array_equal(np.asarray(by_rid[rid].tokens), w)


def test_forced_expiry_event(hyena_model):
    """The "expire" fault kind force-expires one resident request; it
    finishes with ERROR "deadline" and the rest are untouched."""
    cfg, params = hyena_model
    want = _sequential_greedy(cfg, params, "distilled")
    eng, _ = _run_with_faults(cfg, params, "distilled",
                              [{"tick": 5, "kind": "expire"}])
    expired = [r for r in eng.finished if r.finish_reason == "deadline"]
    assert len(expired) == 1 and expired[0].status == "error"
    _check_unaffected_exact(eng, want)


# ---------------------------------------------------------------------------
# checkpoint / restore
# ---------------------------------------------------------------------------
def test_checkpoint_kill_restore_bit_exact(hyena_model, tmp_path):
    """Snapshot a mid-stream engine, "kill" it, restore into a fresh engine
    and drain: every request's greedy tokens are identical to an
    uninterrupted run."""
    cfg, params = hyena_model
    want = _sequential_greedy(cfg, params, "distilled")
    path = str(tmp_path / "engine.ckpt")

    eng_a = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                     mode="distilled")
    for p, g in zip(_prompts(cfg.vocab), GEN_LENS):
        eng_a.submit(p, max_new_tokens=g)
    for _ in range(8):
        if eng_a.has_work:
            eng_a.step()
    save_engine(eng_a, path)
    assert eng_a.resilience.get("checkpoint_saves") == 1
    del eng_a  # the "kill"

    eng_b = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                     mode="distilled")
    restore_engine(eng_b, path)
    assert eng_b.resilience.get("checkpoint_restores") == 1
    eng_b.run()
    by_rid = {r.rid: r for r in eng_b.finished}
    assert sorted(by_rid) == list(range(len(want)))
    for rid, w in enumerate(want):
        assert by_rid[rid].status == "finished"
        np.testing.assert_array_equal(np.asarray(by_rid[rid].tokens), w)


def test_checkpoint_shape_mismatch_rejected(hyena_model, tmp_path):
    cfg, params = hyena_model
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN)
    state = save_engine(eng)
    other = ContinuousBatchingEngine(params, cfg, n_slots=3, max_len=MAX_LEN)
    with pytest.raises(ValueError, match="n_slots"):
        restore_engine(other, state)
    bad = dict(state, format=99)
    with pytest.raises(ValueError, match="format"):
        restore_engine(ContinuousBatchingEngine(params, cfg, n_slots=2,
                                                max_len=MAX_LEN), bad)
    # a snapshot from a HIGHER ladder rung cannot restore into a lower one
    # (the reverse direction — saved lower, engine higher — replays the
    # demotion instead; covered in test_epoch.py)
    up = dict(state, mode="distilled")
    with pytest.raises(ValueError, match="mode"):
        restore_engine(ContinuousBatchingEngine(params, cfg, n_slots=2,
                                                max_len=MAX_LEN,
                                                mode="epoch"), up)


# ---------------------------------------------------------------------------
# guards + compile budget
# ---------------------------------------------------------------------------
def test_zero_steady_state_compiles_with_guards(hyena_model):
    """The fused health checks (and the host-side deadline/watchdog paths)
    add ZERO steady-state XLA compiles after warmup — the acceptance
    criterion that keeps the guards on by default."""
    cfg, params = hyena_model
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode="distilled", health_every=1,
                                   deadline_s=100.0, watchdog_s=100.0)
    eng.warmup(PROMPT_LENS)
    for p, g in zip(_prompts(cfg.vocab), GEN_LENS):
        eng.submit(p, max_new_tokens=g)
    with count_compiles() as scope:
        eng.run()
    assert scope.compiles == 0
    assert all(r.status == "finished" for r in eng.finished)


def test_slot_health_flags_only_poisoned_rows(hyena_model):
    """Unit check of the fused guard: a clean pool is all-healthy; poisoning
    one slot's modal state flags exactly that slot; a modal-norm blowup past
    the pole-derived bound is flagged without any non-finite values."""
    cfg, params = hyena_model
    cache, _ = unzip(init_cache(cfg, 4, MAX_LEN, cache_kind="native",
                                per_slot=True))
    logits = jnp.zeros((4, cfg.vocab), jnp.float32)
    bound = modal_state_bound(params, cfg)
    assert np.isfinite(bound) and bound > 0
    assert np.asarray(slot_health(cache, logits, bound)).all()
    bad = corrupt_cache_slot(cache, 2, "state", float("nan"))
    h = np.asarray(slot_health(bad, logits, bound))
    assert not h[2] and h[[0, 1, 3]].all()
    blown = corrupt_cache_slot(cache, 1, "state", bound * 10.0)
    h2 = np.asarray(slot_health(blown, logits, bound))
    assert not h2[1] and h2[[0, 2, 3]].all()


def test_corrupt_cache_slot_is_surgical(hyena_model):
    """The injector only touches the targeted slot's rows; positions and
    every other slot are bit-identical."""
    cfg, params = hyena_model
    cache, _ = unzip(init_cache(cfg, 3, MAX_LEN, cache_kind="native",
                                per_slot=True))
    bad = corrupt_cache_slot(cache, 1, "state", float("nan"))
    np.testing.assert_array_equal(np.asarray(bad["pos"]),
                                  np.asarray(cache["pos"]))
    for (lk, lv) in cache["groups"].items():
        for k, v in lv.items():
            nv = np.asarray(bad["groups"][lk][k])
            ov = np.asarray(v)
            np.testing.assert_array_equal(nv[:, 0], ov[:, 0])
            np.testing.assert_array_equal(nv[:, 2], ov[:, 2])
            if k in ("x_re", "x_im"):
                assert np.isnan(nv[:, 1]).all()


# ---------------------------------------------------------------------------
# degenerate sampling + plumbing units
# ---------------------------------------------------------------------------
def test_degenerate_sampling_rows():
    """Poisoned or over-filtered logits rows sample a deterministic argmax
    fallback instead of NaN-dependent junk: an all-NaN row yields token 0,
    a top_p=0 row yields its argmax, and healthy rows are untouched."""
    V = 16
    rng = np.random.default_rng(0)
    healthy = rng.normal(size=(V,)).astype(np.float32)
    logits = jnp.stack([jnp.asarray(healthy),
                        jnp.full((V,), jnp.nan),
                        jnp.asarray(healthy)])
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    toks = np.asarray(sample_token_slots(
        keys, logits,
        temperature=jnp.array([0.7, 0.7, 0.7]),
        top_k=jnp.zeros((3,), jnp.int32),
        top_p=jnp.array([1.0, 1.0, 0.0])))
    assert toks[1] == 0                       # all-NaN: masked argmax
    assert toks[2] == int(np.argmax(healthy))  # empty nucleus: argmax
    assert 0 <= toks[0] < V
    # greedy rows ignore NaNs entirely
    g = np.asarray(sample_token_slots(
        keys, logits, temperature=jnp.zeros((3,)),
        top_k=jnp.zeros((3,), jnp.int32), top_p=jnp.ones((3,))))
    assert g[1] == 0 and g[0] == int(np.argmax(healthy))


def test_fault_schedule_json_roundtrip(tmp_path):
    inj = FaultInjector(
        [FaultEvent(tick=3, kind="corrupt", where="conv",
                    value=float("inf")),
         FaultEvent(tick=5, kind="stall", duration_s=0.5),
         {"tick": 9, "kind": "corrupt", "value": "nan", "slot": 1}],
        seed=7)
    back = FaultInjector.from_json(inj.to_json())
    assert back.seed == 7
    assert [e.to_dict() for e in back.events] == \
        [e.to_dict() for e in inj.events]
    p = tmp_path / "sched.json"
    p.write_text(inj.to_json())
    assert len(FaultInjector.from_json(str(p)).events) == 3
    with pytest.raises(ValueError, match="kind"):
        FaultEvent(tick=0, kind="meteor")


def test_resilience_counters_snapshot_stable():
    c = ResilienceCounters()
    snap = c.snapshot()
    assert snap["health_failures"] == 0 and "poisoned" in snap
    c.bump("health_failures")
    c.bump("custom_key", 3)
    assert c.get("health_failures") == 1 and c.get("custom_key") == 3
    assert c.total_faults == 1
    c.reset()
    assert c.total_faults == 0 and c.get("custom_key") == 0


def test_checkpoint_pickles_cleanly(hyena_model, tmp_path):
    """The on-disk snapshot is plain pickle of host data — no jax arrays or
    device handles leak into it."""
    cfg, params = hyena_model
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN)
    eng.submit(_prompts(cfg.vocab)[0], max_new_tokens=4)
    eng.step()
    path = str(tmp_path / "e.ckpt")
    save_engine(eng, path)
    with open(path, "rb") as f:
        state = pickle.load(f)
    leaves = jax.tree.leaves(state["cache"])
    assert all(isinstance(x, np.ndarray) for x in leaves)
    assert state["format"] == 2
    assert "mesh" in state     # format-2 slot-pool layout metadata
    if eng.mesh is None:
        assert state["mesh"] is None
    else:
        assert state["mesh"]["n_shards"] == eng._n_shards
    assert json.dumps(state["resilience"])  # JSON-serializable counters
