"""benchmarks/check_regression.py: the drop gate, the same-run spec-vs-plain
gate (baseline-independent — the fix for the ratchet that preserved a
regressed spec number once it was committed), old-file type tolerance, and
the markdown job summary."""
import json
import sys

import pytest

from benchmarks.check_regression import main as check_main


def _doc(modes, observability=None):
    stream = {"modes": modes}
    if observability is not None:
        stream["observability"] = observability
    return {"serve_stream": stream}


def _mode(tok=1000.0, decode=None, sat=None, **extra):
    m = {"tok_per_s": tok, "decode_tok_per_s": decode or tok}
    if sat is not None:
        m["decode_sat_tok_per_s"] = sat
    m.update(extra)
    return m


def _run(tmp_path, base, new, *args, obs=None):
    bp, np_ = tmp_path / "base.json", tmp_path / "new.json"
    bp.write_text(json.dumps(_doc(base)))
    np_.write_text(json.dumps(_doc(new, observability=obs)))
    argv = sys.argv
    sys.argv = ["check_regression", "--baseline", str(bp), "--new", str(np_),
                *args]
    try:
        return check_main()
    finally:
        sys.argv = argv


def test_pass_and_drop(tmp_path):
    base = {"distilled": _mode(1000), "distilled_spec": _mode(1100, sat=1300)}
    good = {"distilled": _mode(980, sat=1000),
            "distilled_spec": _mode(1050, sat=1200)}
    assert _run(tmp_path, base, good) == 0
    bad = {"distilled": _mode(500, sat=1000),
           "distilled_spec": _mode(1050, sat=1200)}
    assert _run(tmp_path, base, bad) == 1


def test_spec_gate_is_same_run_not_baseline(tmp_path):
    """A regressed spec number in the BASELINE must not grandfather a spec
    mode that trails plain decode in the NEW run — and vice versa, spec
    keeping up with plain passes regardless of the baseline's spec entry."""
    base = {"distilled": _mode(1000, sat=2800),
            "distilled_spec": _mode(550, sat=1500)}   # committed regression
    trail = {"distilled": _mode(1000, sat=2800),
             "distilled_spec": _mode(1000, sat=2000)}  # still trails plain
    assert _run(tmp_path, base, trail) == 1
    win = {"distilled": _mode(1000, sat=2800),
           "distilled_spec": _mode(1000, sat=3500)}
    assert _run(tmp_path, base, win) == 0
    # ratio knob + disable
    assert _run(tmp_path, base, win, "--spec-ratio", "1.5") == 1
    assert _run(tmp_path, base, trail, "--spec-ratio", "0") == 0


def test_sat_metric_preferred_with_stream_fallback(tmp_path):
    """The gate compares decode_sat_tok_per_s when both modes report it and
    falls back to the stream decode_tok_per_s for files that predate it."""
    base = {"distilled": _mode(1000)}
    # sat says spec wins even though the noisy stream number trails
    new = {"distilled": _mode(1000, decode=900, sat=2800),
           "distilled_spec": _mode(990, decode=800, sat=3300)}
    assert _run(tmp_path, base, new) == 0
    # no sat metric anywhere: stream decode decides
    old_style = {"distilled": _mode(1000, decode=900),
                 "distilled_spec": _mode(990, decode=800)}
    assert _run(tmp_path, base, old_style) == 1


def test_tolerates_old_float_counts_and_missing_modes(tmp_path):
    base = {"distilled": {"tok_per_s": 1000.0, "n_requests": 16.0,
                          "n_tokens": 516.0},
            "weird": {"tok_per_s": "not-a-number"}}
    new = {"distilled": _mode(1000, sat=2800), "weird": {"tok_per_s": None},
           "distilled_spec": _mode(1000, sat=2900),
           "extra_mode": _mode(5)}
    assert _run(tmp_path, base, new) == 0


def test_summary_markdown(tmp_path):
    base = {"distilled": _mode(1000)}
    new = {"distilled": _mode(1000, sat=2800),
           "distilled_spec": _mode(1100, sat=3300, acceptance_rate=0.97,
                                   tokens_per_slot_round=4.6, spec_k=4,
                                   draft_order=16, spec_branch=1,
                                   autotune=[{"config": "plain",
                                              "decode_tok_per_s": 2800.0},
                                             {"config": "k4/d16",
                                              "decode_tok_per_s": 3300.0,
                                              "acceptance": 1.0}])}
    out = tmp_path / "summary.md"
    assert _run(tmp_path, base, new, "--summary", str(out)) == 0
    text = out.read_text()
    assert "| distilled_spec " in text and "0.97" in text
    assert "k4/d16" in text and "chosen: **k4/d16/b1**" in text
    assert "all serving throughput checks passed" in text


def test_missing_spec_mode_fails(tmp_path):
    base = {"distilled": _mode(1000)}
    new = {"distilled": _mode(1000, sat=2800)}
    assert _run(tmp_path, base, new) == 1
    assert _run(tmp_path, base, new, "--spec-ratio", "0") == 0


# -- observability gate ------------------------------------------------------

def _obs(off=2800.0, on=2780.0, compiles=0, **extra):
    row = {"decode_sat_tok_per_s_off": off, "decode_sat_tok_per_s_on": on,
           "overhead_frac": (off - on) / off if off else None,
           "steady_state_compiles": compiles, "trace_events": 4096,
           "trace_dropped": 0, "metric_series": 20}
    row.update(extra)
    return row


_GOOD = {"distilled": _mode(1000, sat=2800),
         "distilled_spec": _mode(1050, sat=3200)}


def test_observability_gate_same_run(tmp_path):
    """Telemetry overhead is gated against the SAME run's telemetry-off
    number — within budget passes, over budget fails, knob adjusts."""
    base = {"distilled": _mode(1000)}
    assert _run(tmp_path, base, _GOOD, obs=_obs(on=2780.0)) == 0   # 0.7%
    assert _run(tmp_path, base, _GOOD, obs=_obs(on=2600.0)) == 1   # 7.1%
    assert _run(tmp_path, base, _GOOD, obs=_obs(on=2600.0),
                *("--obs-overhead", "0.1")) == 0
    assert _run(tmp_path, base, _GOOD, obs=_obs(on=2600.0),
                *("--obs-overhead", "0")) == 0                     # disabled
    # measurement noise can put "on" ahead of "off": negative overhead passes
    assert _run(tmp_path, base, _GOOD, obs=_obs(on=2850.0)) == 0


def test_observability_gate_compiles_and_bad_rows(tmp_path):
    """Any steady-state compile with telemetry on fails; a malformed row
    (missing the on/off numbers) fails rather than silently passing."""
    base = {"distilled": _mode(1000)}
    assert _run(tmp_path, base, _GOOD, obs=_obs(compiles=2)) == 1
    assert _run(tmp_path, base, _GOOD,
                obs={"steady_state_compiles": 0}) == 1
    assert _run(tmp_path, base, _GOOD, obs=_obs(off=0.0, on=0.0)) == 1


def test_observability_missing_row_is_tolerated(tmp_path):
    """Bench files predating the observability row skip the gate — the
    drop/spec gates still run (and can still fail)."""
    base = {"distilled": _mode(1000)}
    assert _run(tmp_path, base, _GOOD) == 0
    bad = {"distilled": _mode(400, sat=1000),
           "distilled_spec": _mode(420, sat=1100)}
    assert _run(tmp_path, base, bad) == 1


def test_observability_summary_markdown(tmp_path):
    base = {"distilled": _mode(1000)}
    out = tmp_path / "summary.md"
    assert _run(tmp_path, base, _GOOD, "--summary", str(out),
                obs=_obs(on=2780.0)) == 0
    text = out.read_text()
    assert "Observability overhead" in text
    assert "2780" in text and "2800" in text


# -- chaos gate -------------------------------------------------------------

def _chaos_mode(expected=16, completed=16, ok=14, resilience=None, **extra):
    m = {"n_requests_expected": expected, "n_completed": completed,
         "n_ok": ok, "n_errors": completed - ok,
         "unrecovered": expected - completed, "total_faults": 5,
         "resilience": resilience or {"health_failures": 2,
                                      "slot_reprefills": 2,
                                      "dispatch_faults": 1,
                                      "deadline_expiries": 1,
                                      "watchdog_trips": 1, "poisoned": 1}}
    m.update(extra)
    return m


def _run_chaos(tmp_path, chaos_modes, *args):
    cp = tmp_path / "chaos.json"
    cp.write_text(json.dumps({"serve_chaos": {"modes": chaos_modes}}))
    argv = sys.argv
    sys.argv = ["check_regression", "--chaos", str(cp), *args]
    try:
        return check_main()
    finally:
        sys.argv = argv


def test_chaos_gate_standalone(tmp_path):
    """Recovered faults (error-status completions included) pass; a request
    that never reached a terminal status fails. No --baseline needed."""
    good = {"distilled": _chaos_mode(), "cached_conv": _chaos_mode(ok=16)}
    assert _run_chaos(tmp_path, good) == 0
    hung = {"distilled": _chaos_mode(),
            "cached_conv": _chaos_mode(completed=15, ok=15)}
    assert _run_chaos(tmp_path, hung) == 1


def test_chaos_gate_empty_doc_fails(tmp_path):
    """A chaos file with no modes means the bench crashed before reporting —
    that must fail, not silently pass."""
    cp = tmp_path / "chaos.json"
    cp.write_text(json.dumps({}))
    argv = sys.argv
    sys.argv = ["check_regression", "--chaos", str(cp)]
    try:
        assert check_main() == 1
    finally:
        sys.argv = argv


def test_chaos_summary_reports_recovered_counts(tmp_path):
    """Recovered-fault counters land in the summary table but do not gate:
    a mode with many absorbed faults still passes when all requests
    completed."""
    modes = {"distilled": _chaos_mode(
        resilience={"health_failures": 9, "slot_reprefills": 9,
                    "dispatch_faults": 3, "deadline_expiries": 2,
                    "watchdog_trips": 4, "poisoned": 2})}
    out = tmp_path / "summary.md"
    assert _run_chaos(tmp_path, modes, "--summary", str(out)) == 0
    text = out.read_text()
    assert "Chaos run" in text and "| distilled " in text and "| 9 " in text


def test_chaos_alongside_throughput_gate(tmp_path):
    """--baseline and --chaos compose: either gate alone can fail the run."""
    base = {"distilled": _mode(1000)}
    new = {"distilled": _mode(1000, sat=2800),
           "distilled_spec": _mode(1000, sat=2900)}
    cp = tmp_path / "chaos.json"
    cp.write_text(json.dumps({"serve_chaos": {"modes": {
        "distilled": _chaos_mode(completed=12, ok=12)}}}))
    assert _run(tmp_path, base, new, "--chaos", str(cp)) == 1
    cp.write_text(json.dumps({"serve_chaos": {"modes": {
        "distilled": _chaos_mode()}}}))
    assert _run(tmp_path, base, new, "--chaos", str(cp)) == 0
