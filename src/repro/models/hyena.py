"""Multi-head Hyena (paper Sec. 4) and its three inference modes.

Training/prefill mode evaluates the long convolution with an FFT; decode mode
either (a) uses the LaughingHyena-distilled modal SSM (O(d) per token), or
(b) falls back to the cached-convolution baseline (Lemma 2.1, O(t) per token)
for pre-distillation models.

Filter parametrization follows Hyena: an implicit MLP with sine activations
over positional features, modulated by a learned exponential-decay window.
MultiHyena ties filters across channels into M heads: head m's single filter
h^m is applied to all D/M channels of that head.

Deployment form. The paper's Sec.-4 operator is written with a per-head outer
product z^m = k^m (x) v^m in R^{L x N x N}. Materializing that tensor costs
L*N*D activations; the paper's own memory measurements (Fig. 5.4: constant,
small) imply the deployed operator is the elementwise Hyena gating with tied
filters (the N=1-per-subhead specialization). We therefore use the elementwise
form y = q . (h * (k . v)) with M tied filters as the production operator, and
provide `outer_product_op` (the literal Sec.-4 form) for the associative-recall
validation of Theorem 4.1 at small widths. See DESIGN.md #hardware-adaptation.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Param
from repro.kernels.ssm_decode.ops import ssm_decode
from repro.models.layers import (
    NOCTX, ShardCtx, apply_short_conv, conv_tail_gather, dense_init,
    init_short_conv, short_conv_chunk, short_conv_step,
)


# ---------------------------------------------------------------------------
# Implicit filter (sine-activated MLP over positional features)
# ---------------------------------------------------------------------------
def positional_features(L: int, emb: int) -> jnp.ndarray:
    """(L, emb) features: normalized time + exponentially spaced sinusoids."""
    t = jnp.linspace(0.0, 1.0, L)[:, None]
    nb = (emb - 1) // 2
    f = jnp.asarray(np.linspace(1e-4, nb - 1, nb))[None, :]
    z = jnp.exp(-1j * f * t * 2 * math.pi)
    return jnp.concatenate([t, z.real, z.imag], axis=-1).astype(jnp.float32)


def init_filter_mlp(key, hcfg, M: int):
    """Implicit filter MLP producing M tied filters."""
    order, emb = hcfg.filter_order, hcfg.filter_emb
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "w1": dense_init(k1, (emb, order), (None, "filters"), in_dim=emb),
        "w2": dense_init(k2, (order, order), ("filters", "filters"), in_dim=order),
        "w3": dense_init(k3, (order, M), ("filters", None), in_dim=order),
        "decay": Param(jnp.linspace(0.5, 3.5, M), (None,)),   # window rates
        "bias": Param(jax.random.normal(k4, (M,)) * 0.1, (None,)),  # h0 term
    }


def init_filter_ssm(key, hcfg, M: int):
    """H3-style filter: a trainable diagonal SSM in modal form (App. E.3.1's
    family). The filter IS an order-ssm_state recurrence already, so
    distillation to a lower order is exact model-order reduction."""
    d = hcfg.ssm_state
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "log_a": Param(jnp.log(jax.random.uniform(k1, (M, d), minval=0.6,
                                                  maxval=0.999)),
                       (None, "state")),
        "theta": Param(jax.random.uniform(k2, (M, d), maxval=math.pi),
                       (None, "state")),
        "R_re": Param(jax.random.normal(k3, (M, d)) / d, (None, "state")),
        "R_im": Param(jnp.zeros((M, d)), (None, "state")),
        "bias": Param(jnp.zeros((M,)), (None,)),
    }


def materialize_filters(params, L: int, hcfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (h, h0): h (M, L) long filters, h0 (M,) passthrough."""
    if hcfg.filter_param == "ssm":
        # h[t] = Re sum_n R_n lam_n^(t-1), t >= 1; h[0] = 0 (bias is the
        # passthrough) — Lemma 3.1 evaluation, same math as eval_filter.
        t = jnp.arange(L - 1, dtype=jnp.float32)
        mag = jnp.exp(params["log_a"][..., None] * t)
        ang = params["theta"][..., None] * t
        tail = jnp.einsum("md,mdl->ml", params["R_re"], mag * jnp.cos(ang)) \
            - jnp.einsum("md,mdl->ml", params["R_im"], mag * jnp.sin(ang))
        h = jnp.concatenate([jnp.zeros_like(tail[:, :1]), tail], axis=-1)
        return h, params["bias"]
    z = positional_features(L, hcfg.filter_emb)
    w0 = hcfg.sine_freq
    h = jnp.sin(w0 * (z @ params["w1"]))
    h = jnp.sin(w0 * (h @ params["w2"]))
    h = h @ params["w3"]                                   # (L, M)
    if hcfg.modulate:
        t = jnp.linspace(0.0, 1.0, L)[:, None]
        window = jnp.exp(-jnp.abs(params["decay"])[None, :] * t * 8.0)
        h = h * window
    # normalize per filter (stabilizes training; standard in Hyena impls)
    h = h / (jnp.sum(jnp.abs(h), axis=0, keepdims=True) + 1e-8)
    return h.T, params["bias"]                             # (M, L), (M,)


# ---------------------------------------------------------------------------
# FFT long convolution
# ---------------------------------------------------------------------------
def fft_conv(u: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Causal conv of u (B, L, D) with per-channel filters h (D, L) or (M, L)
    broadcast over channel groups. Returns (B, L, D) in u.dtype."""
    B, L, D = u.shape
    n = 2 * L
    uf = jnp.fft.rfft(u.astype(jnp.float32), n=n, axis=1)       # (B, F, D)
    hf = jnp.fft.rfft(h.astype(jnp.float32), n=n, axis=-1)      # (D|M, F)
    if hf.shape[0] != D:                                         # tied heads
        M = hf.shape[0]
        hf = jnp.repeat(hf, D // M, axis=0)
    y = jnp.fft.irfft(uf * hf.T[None], n=n, axis=1)[:, :L, :]
    return y.astype(u.dtype)


def fft_conv_sharded(u: jnp.ndarray, h: jnp.ndarray, ctx) -> jnp.ndarray:
    """fft_conv under shard_map: GSPMD cannot partition FFT ops and falls back
    to all-gathering the full global-batch FFT buffers (measured: ~120 GB per
    device per layer at 1.3B/train_4k). The FFT runs along the *sequence*
    axis, which is unsharded — so mapping over (batch, channel) shards makes
    the op embarrassingly parallel with ZERO collectives."""
    from repro.distributed.sharding import resolve_spec, shard_map_compat
    mesh = ctx.mesh
    if mesh is None:
        return fft_conv(u, h)
    B, L, D = u.shape
    if h.shape[0] != D:
        h = jnp.repeat(h, D // h.shape[0], axis=0)               # (D, L)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec_u = resolve_spec((B, L, D), ("batch", None, "qkv"), ctx.rules,
                          mesh_shape)
    spec_h = resolve_spec((D, L), ("qkv", None), ctx.rules, mesh_shape)
    u = jax.lax.with_sharding_constraint(
        u, jax.sharding.NamedSharding(mesh, spec_u))

    def local(u_blk, h_blk):
        return fft_conv(u_blk, h_blk)

    # unchecked replication: h is replicated along 'data', so its cotangent
    # needs the conservative psum the unchecked transpose inserts.
    return shard_map_compat(local, mesh, (spec_u, spec_h), spec_u)(u, h)


# ---------------------------------------------------------------------------
# MultiHyena block
# ---------------------------------------------------------------------------
def init_hyena_block(key, cfg):
    d = cfg.d_model
    h = cfg.hyena
    kq, kk, kv, ko, kc, kf = jax.random.split(key, 6)
    filter_init = (init_filter_ssm if h.filter_param == "ssm"
                   else init_filter_mlp)
    return {
        "wqkv": dense_init(kq, (d, 3, d), ("embed", None, "qkv"), in_dim=d),
        "wo": dense_init(ko, (d, d), ("qkv", "embed"), in_dim=d),
        "short_conv": init_short_conv(kc, 3 * d, h.short_conv),
        "filter": filter_init(kf, h, h.n_filter_heads),
        # Distilled modal SSM (populated by repro.core.distill; initialized
        # to a stable random system so decode lowers before distillation).
        # Paper order d == real state dim == 2 x (free complex modes): the
        # modal form takes Re[.], so d/2 conjugate-pair representatives are
        # stored (App. B.1) and the state is d/2 complex = d reals.
        "distilled": init_modal_params(kv, h.n_filter_heads, h.distill_order // 2),
    }


def init_modal_params(key, M: int, d: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "log_a": Param(jnp.log(jax.random.uniform(k1, (M, d), minval=0.7, maxval=0.99)), (None, "state")),
        "theta": Param(jax.random.uniform(k2, (M, d), maxval=math.pi), (None, "state")),
        "R_re": Param(jax.random.normal(k3, (M, d)) / d, (None, "state")),
        "R_im": Param(jnp.zeros((M, d)), (None, "state")),
        "h0": Param(jnp.zeros((M,)), (None,)),
    }


def modal_poles_residues(dp) -> Tuple[jnp.ndarray, jnp.ndarray]:
    lam = jnp.exp(dp["log_a"]) * jnp.exp(1j * dp["theta"])
    R = dp["R_re"] + 1j * dp["R_im"]
    return lam, R


def hyena_block(params, x, cfg, *, ctx: ShardCtx = NOCTX,
                filters: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                return_cache: bool = False, cache_kind: str = "native",
                lengths: Optional[jnp.ndarray] = None,
                filter_len: Optional[int] = None):
    """Full-sequence MultiHyena (train / prefill). x: (B, S, D).

    cache_kind selects what `return_cache` collects:
      * "native" — distilled modal SSM state (O(d) recurrent decode);
      * "conv"   — the k.v product sequence for the Lemma-2.1 cached-conv
                   decode baseline (O(t) per token);
      * "epoch"  — the conv buffers plus the FutureFill epoch state
                   (exact decode at amortized O(sqrt(L) log L) per token).

    `lengths` (B,) marks per-row true prompt lengths for bucketed (right-
    padded) prefill: the collected caches are masked/gathered so padded
    positions never enter the modal state, the conv tail, or the kv buffer.
    The causal conv itself needs no masking — right padding cannot reach
    positions < length.

    `filter_len` materializes the implicit filter at a fixed reference
    length and slices it to S. The implicit filter is a function of
    normalized time, so its values depend on the materialization length —
    serving passes filter_len=max_len so exact-length, bucket-padded, and
    chunked prefill (and the cached-conv decode path) all see identical
    filter values; training leaves it None (materialize at S, as before).
    """
    B, S, D = x.shape
    qkv = jnp.einsum("bsd,dge->bsge", x, params["wqkv"].astype(x.dtype))
    qkv = qkv.reshape(B, S, 3 * D)
    pre_conv = qkv
    qkv = apply_short_conv(params["short_conv"], qkv)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = ctx.cs(q, ("batch", None, "qkv"))
    if filters is None:
        Lf = S if filter_len is None else max(int(filter_len), S)
        filters = materialize_filters(params["filter"], Lf, cfg.hyena)
        filters = (filters[0][:, :S], filters[1])
    h, h0 = filters                                       # (M, S), (M,)
    kv = ctx.cs(k * v, ("batch", None, "qkv"))
    y = fft_conv_sharded(kv, h, ctx) + \
        kv * jnp.repeat(h0, D // h.shape[0]).astype(x.dtype)
    y = ctx.cs(q * y, ("batch", None, "qkv"))
    out = jnp.einsum("bse,ed->bsd", y, params["wo"].astype(x.dtype))
    if return_cache:
        w = cfg.hyena.short_conv - 1
        if lengths is None:
            conv = pre_conv[:, S - w:, :].astype(jnp.float32)
            kv_c = kv
        else:
            # conv tail = the w positions ending at each row's true length
            conv = conv_tail_gather(pre_conv, w, lengths).astype(jnp.float32)
            kv_c = jnp.where(
                jnp.arange(S)[None, :, None] < lengths[:, None, None], kv, 0)
        if cache_kind == "conv":
            cache = {"conv": conv, "kv": kv_c.astype(jnp.float32)}
        elif cache_kind == "epoch":
            # FutureFill epoch cache: prefill leaves epoch 0 with `fut`
            # empty — the first decode tick's flush bakes the whole prefix
            # in via one FFT (exact either way; see hyena_decode_epoch).
            cache = {"conv": conv, "kv": kv_c.astype(jnp.float32),
                     "fut": jnp.zeros((B, S, D), jnp.float32),
                     "epoch": jnp.zeros((B,), jnp.int32)}
        else:
            # modal SSM prefill (Sec. 3.4, O(dT) matmul variant — MXU friendly)
            xr, xi = modal_prefill_state(params["distilled"], kv_c, cfg.hyena,
                                         lengths=lengths)
            cache = {"conv": conv, "x_re": xr, "x_im": xi}
        return out, cache
    return out


def modal_prefill_state(dp, u, hcfg, lengths=None):
    """State after consuming u (B, T, D): x_L[n] = sum_{t<L} lam_n^{L-1-t} u_t.

    Evaluated as a (d x T) Vandermonde-basis matmul per filter head — the
    O(dT) strategy of Sec. 3.4, which maps onto the MXU. The input is
    time-reversed first (u_rev[j] = u[L-1-j]) so the basis lam^j is shared
    across rows; with per-row `lengths` the reversal is a masked gather from
    each row's true end, which is what makes bucket-padded prefill exact.
    Returns (re, im) each (B, D, d).
    """
    B, T, D = u.shape
    M, d = dp["log_a"].shape
    N = D // M
    expo = jnp.arange(T, dtype=jnp.float32)                      # lam^j
    mag = jnp.exp(dp["log_a"][..., None] * expo)                 # (M, d, T)
    ang = dp["theta"][..., None] * expo
    br = mag * jnp.cos(ang)
    bi = mag * jnp.sin(ang)
    uf = u.astype(jnp.float32)
    if lengths is None:
        u_rev = uf[:, ::-1, :]
    else:
        idx = lengths[:, None] - 1 - jnp.arange(T)[None, :]      # (B, T)
        u_rev = jnp.where(idx[..., None] >= 0,
                          jnp.take_along_axis(uf, jnp.clip(idx, 0)[..., None],
                                              axis=1), 0.0)
    ur = u_rev.reshape(B, T, M, N)
    xr = jnp.einsum("btmi,mdt->bmid", ur, br).reshape(B, D, d)
    xi = jnp.einsum("btmi,mdt->bmid", ur, bi).reshape(B, D, d)
    return xr, xi


# ---------------------------------------------------------------------------
# Decode: distilled modal recurrence (Prop. 3.3) — O(d) per token per channel
# ---------------------------------------------------------------------------
def init_hyena_cache(batch: int, cfg, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    h = cfg.hyena
    return {
        "conv": jnp.zeros((batch, h.short_conv - 1, 3 * d), dtype),
        # modal state: d/2 conjugate-pair modes stored as re/im = d reals
        # per channel — exactly the paper's order-d memory footprint.
        "x_re": jnp.zeros((batch, d, h.distill_order // 2), dtype),
        "x_im": jnp.zeros((batch, d, h.distill_order // 2), dtype),
    }


def hyena_decode(params, cache, x, cfg, *, ctx: ShardCtx = NOCTX):
    """One-token decode with the distilled SSM. x: (B, 1, D)."""
    B, _, D = x.shape
    h = cfg.hyena
    M, N = h.n_filter_heads, D // h.n_filter_heads
    qkv = jnp.einsum("bsd,dge->bsge", x, params["wqkv"].astype(x.dtype))
    qkv = qkv.reshape(B, 3 * D)
    conv_cache, qkv = short_conv_step(params["short_conv"], cache["conv"], qkv)
    q, k, v = jnp.split(qkv, 3, axis=-1)                   # (B, D) each
    u = (k * v).astype(jnp.float32)                        # (B, D)

    dp = params["distilled"]
    log_a = jnp.repeat(dp["log_a"], N, axis=0)               # (D, d)
    theta = jnp.repeat(dp["theta"], N, axis=0)
    R_re = jnp.repeat(dp["R_re"], N, axis=0)
    R_im = jnp.repeat(dp["R_im"], N, axis=0)
    h0 = jnp.repeat(dp["h0"], N, axis=0)

    # Paper convention (Prop. 3.3): y_t = Re[R . x_t] + h0 u_t, then
    # x_{t+1} = lam x_t + u_t, with x_t holding the state after u_{t-1}.
    # Dispatch through the ops wrapper: fused Pallas kernel on TPU (one HBM
    # pass over the state), jnp reference elsewhere.
    xr, xi = cache["x_re"], cache["x_im"]
    y, nxr, nxi = ssm_decode(xr, xi, u, log_a, theta, R_re, R_im, h0)
    out = (q.astype(jnp.float32) * y).astype(x.dtype)
    new_cache = {"conv": conv_cache, "x_re": nxr, "x_im": nxi}
    return new_cache, jnp.einsum("be,ed->bd", out, params["wo"].astype(x.dtype))[:, None, :]


# ---------------------------------------------------------------------------
# Decode baseline: cached convolution (Lemma 2.1) — O(t) per token
# ---------------------------------------------------------------------------
def init_hyena_conv_cache(batch: int, max_len: int, cfg, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.hyena.short_conv - 1, 3 * cfg.d_model), dtype),
        "kv": jnp.zeros((batch, max_len, cfg.d_model), dtype),   # past k.v products
    }


def hyena_decode_cached_conv(params, cache, x, pos, cfg, filters,
                             *, ctx: ShardCtx = NOCTX):
    """Naive cached-conv decode: y_t = q_t * sum_j h_{t-j} (kv)_j.

    pos: scalar int32 or a per-slot (B,) vector (continuous batching: each
    resident request decodes at its own position).
    """
    B, _, D = x.shape
    h_full, h0 = filters                                   # (M, Lmax), (M,)
    M = h_full.shape[0]
    qkv = jnp.einsum("bsd,dge->bsge", x, params["wqkv"].astype(x.dtype)).reshape(B, 3 * D)
    conv_cache, qkv = short_conv_step(params["short_conv"], cache["conv"], qkv)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    pos = jnp.asarray(pos, jnp.int32)
    Lmax = cache["kv"].shape[1]
    if pos.ndim == 1:
        widx = jnp.minimum(pos, Lmax - 1)                  # clamp idle slots
        kv_cache = cache["kv"].at[jnp.arange(B), widx].set(
            (k * v).astype(cache["kv"].dtype))
        # h_rev[b, j] = h[pos_b - j] for j <= pos_b else 0
        idx = pos[:, None] - jnp.arange(Lmax)[None, :]     # (B, Lmax)
        hm = jnp.take(h_full, jnp.clip(idx, 0), axis=1)    # (M, B, Lmax)
        hr = jnp.where((idx >= 0)[None], hm, 0.0)
        hr = jnp.repeat(hr, D // M, axis=0)                # (D, B, Lmax)
        y = jnp.einsum("bld,dbl->bd", kv_cache, hr.astype(kv_cache.dtype))
    else:
        kv_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["kv"], (k * v)[:, None, :].astype(cache["kv"].dtype), pos,
            axis=1)
        # h_rev[j] = h[pos - j] for j <= pos else 0
        idx = pos - jnp.arange(Lmax)
        hr = jnp.where((idx >= 0)[None, :],
                       jnp.take(h_full, jnp.clip(idx, 0), axis=1), 0.0)
        hr = jnp.repeat(hr, D // M, axis=0)                # (D, Lmax)
        y = jnp.einsum("bld,dl->bd", kv_cache, hr.astype(kv_cache.dtype))
    y = y.astype(jnp.float32) + jnp.repeat(h0, D // M) * \
        (k * v).astype(jnp.float32)
    # keep the accumulation in f32, emit in the residual-stream dtype (the
    # short-conv cache is f32, so q/k/v promote even under bf16 configs)
    out = (q.astype(jnp.float32) * y).astype(x.dtype)
    new_cache = {"conv": conv_cache, "kv": kv_cache}
    return new_cache, jnp.einsum("be,ed->bd", out, params["wo"].astype(x.dtype))[:, None, :]


# ---------------------------------------------------------------------------
# Decode: epoched convolution (FutureFill / Flash Inference) — exact output
# from the TRUE long filter at amortized O(sqrt(L) log L) per token
# ---------------------------------------------------------------------------
def epoch_tail(max_len: int) -> int:
    """Online-tail length E for the epoched decode: the smallest power of two
    >= sqrt(max_len), clamped to max_len. A flush re-runs the full FFT every
    ~E tokens per slot, so the per-token amortized cost is
    O(E + (L/E) log L) ~ O(sqrt(L) log L) — the FutureFill schedule."""
    target = max(1, math.isqrt(max(max_len - 1, 0)) + 1)
    return min(1 << (target - 1).bit_length(), max_len)


def init_hyena_epoch_cache(batch: int, max_len: int, cfg, dtype=jnp.float32):
    """Epoch cache = the cached-conv buffers plus the FutureFill state:
    `fut` (B, max_len, D) holds the consumed prefix's precomputed
    contribution to every future output position, `epoch` (B,) int32 the
    per-slot count of prefix tokens baked into it."""
    c = init_hyena_conv_cache(batch, max_len, cfg, dtype)
    c["fut"] = jnp.zeros((batch, max_len, cfg.d_model), dtype)
    c["epoch"] = jnp.zeros((batch,), jnp.int32)
    return c


def hyena_decode_epoch(params, cache, x, pos, cfg, filters,
                       *, ctx: ShardCtx = NOCTX):
    """One-token epoched decode (FutureFill): y_t exact from the true long
    filter, amortized O(sqrt(L) log L) per token.

    The causal conv splits at the per-slot epoch boundary e:
    fut[t] = sum_{j<e} h[t-j] (kv)_j is precomputed for EVERY future t by one
    FFT at the last flush, so the step only adds the short online tail
    sum_{j in [e, t]} h[t-j] (kv)_j — at most E = epoch_tail terms. When the
    tail would exceed E the flush re-runs the FFT over the kv buffer (rows
    past t are zero, so the full causal conv IS the prefix contribution to
    every future position) under a lax.cond: one executable, zero
    steady-state compiles, FFT cost amortized over ~E tokens per slot.
    Prefill leaves epoch 0 with fut empty, so a freshly admitted slot's
    first decode tick bakes the whole prompt in — exact either way.
    pos: scalar int32 or per-slot (B,).
    """
    B, _, D = x.shape
    h_full, h0 = filters                                   # (M, Lmax), (M,)
    M = h_full.shape[0]
    Lmax = cache["kv"].shape[1]
    E = epoch_tail(Lmax)
    qkv = jnp.einsum("bsd,dge->bsge", x,
                     params["wqkv"].astype(x.dtype)).reshape(B, 3 * D)
    conv_cache, qkv = short_conv_step(params["short_conv"], cache["conv"], qkv)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    kv_t = (k * v).astype(cache["kv"].dtype)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((B,), pos, jnp.int32)
    widx = jnp.minimum(pos, Lmax - 1)                      # clamp idle slots
    kv_cache = cache["kv"].at[jnp.arange(B), widx].set(kv_t)
    epoch = jnp.asarray(cache["epoch"], jnp.int32)
    flush = (pos + 1 - epoch) > E                          # tail incl. token t

    def do_flush(fut):
        full = fft_conv(kv_cache, h_full).astype(fut.dtype)
        return jnp.where(flush[:, None, None], full, fut)

    fut = jax.lax.cond(jnp.any(flush), do_flush, lambda f: f, cache["fut"])
    new_epoch = jnp.where(flush, pos + 1, epoch)
    # online tail over [epoch', pos]: <= E terms, empty right after a flush
    idx = pos[:, None] - jnp.arange(E)[None, :]            # (B, E)
    keep = (idx >= new_epoch[:, None]) & (idx >= 0)
    kv_g = jnp.take_along_axis(kv_cache, jnp.clip(idx, 0)[..., None], axis=1)
    h_tail = jnp.repeat(h_full[:, :E], D // M, axis=0)     # (D, E)
    y = jnp.einsum("bkd,dk->bd", jnp.where(keep[..., None], kv_g, 0),
                   h_tail.astype(kv_cache.dtype))
    fut_t = jnp.take_along_axis(fut, widx[:, None, None], axis=1)[:, 0, :]
    y = y.astype(jnp.float32) + fut_t.astype(jnp.float32) + \
        jnp.repeat(h0, D // M) * kv_t.astype(jnp.float32)
    out = (q.astype(jnp.float32) * y).astype(x.dtype)
    new_cache = {"conv": conv_cache, "kv": kv_cache, "fut": fut,
                 "epoch": new_epoch}
    return new_cache, jnp.einsum("be,ed->bd", out,
                                 params["wo"].astype(x.dtype))[:, None, :]


# ---------------------------------------------------------------------------
# Multi-token decode on the decode cache (speculative verify / replay)
# ---------------------------------------------------------------------------
def _short_conv_rows(params, tail, u, active_len):
    """Per-row resumable short conv: u (B, C, D'), tail (B, W-1, D').
    Returns (new_tail, y (B, C, D'), ext (B, W-1+C, D')) where row b's new
    tail is the W-1 inputs ending at its own active_len (inputs past it
    never enter the carried state). `ext` is the concatenated input window —
    `conv_tail_gather(ext, W-1, W-1+j)` yields the tail after ANY j <= C
    tokens, which is how the speculative selection-commit rolls a conv tail
    to the accepted position without a replay."""
    from repro.models.layers import conv_tail_gather
    w = params["w"]
    width = w.shape[0]
    C = u.shape[1]
    ext = jnp.concatenate([tail, u], axis=1)          # promotes to f32 tail
    wc = w.astype(ext.dtype)
    y = jnp.zeros_like(ext[:, width - 1:, :])
    for i in range(width):
        y = y + ext[:, i:i + C, :] * wc[i]
    if width == 1:
        return tail, y, ext
    new_tail = conv_tail_gather(ext, width - 1, (width - 1) + active_len)
    return new_tail.astype(tail.dtype), y, ext


def hyena_decode_chunk(params, cache, x, active_len, cfg, *,
                       ctx: ShardCtx = NOCTX, return_states: bool = False):
    """Consume up to C tokens per slot with the distilled modal recurrence.
    x: (B, C, D); active_len (B,) — row b's modal state and conv tail advance
    by exactly its first active_len tokens (the rest compute garbage outputs
    the caller ignores).

    The state trajectory is an unrolled C-step recurrence (C is tiny — the
    speculation window) with per-row keep-masking, using the SAME update
    formulas as the one-token `ssm_decode` step (lam precomputed once,
    bit-identical values), so a replay over an accepted prefix is
    bit-identical to having decoded those tokens sequentially. The
    Prop.-3.3 readout y_j = Re[R X_j] + h0 u_j is then evaluated for all
    positions in ONE batched einsum over the stacked states — the verify
    path is op-overhead-bound, so keeping the scan body to the 6 state
    multiplies matters. With return_states=True the per-step trajectory and
    the conv input window are also returned, so a speculative commit can
    SELECT the state after any accepted prefix length instead of replaying
    (states past a row's active_len are frozen — only indices <= active_len
    are ever selected)."""
    B, C, D = x.shape
    h = cfg.hyena
    N = D // h.n_filter_heads
    qkv = jnp.einsum("bsd,dge->bsge", x, params["wqkv"].astype(x.dtype))
    qkv = qkv.reshape(B, C, 3 * D)
    active_len = jnp.asarray(active_len, jnp.int32)
    new_tail, qkv, ext = _short_conv_rows(params["short_conv"], cache["conv"],
                                          qkv, active_len)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    u = (k * v).astype(jnp.float32)                       # (B, C, D)
    valid = jnp.arange(C)[None, :] < active_len[:, None]  # (B, C)

    dp = params["distilled"]
    log_a = jnp.repeat(dp["log_a"], N, axis=0)            # (D, d)
    theta = jnp.repeat(dp["theta"], N, axis=0)
    R_re = jnp.repeat(dp["R_re"], N, axis=0)
    R_im = jnp.repeat(dp["R_im"], N, axis=0)
    h0 = jnp.repeat(dp["h0"], N, axis=0)
    lr = jnp.exp(log_a) * jnp.cos(theta)                  # as in ssm_decode
    li = jnp.exp(log_a) * jnp.sin(theta)

    def body(carry, inp):
        xr, xi = carry
        u_t, keep = inp                                   # (B, D), (B,)
        nxr = lr[None] * xr - li[None] * xi + u_t[..., None]
        nxi = lr[None] * xi + li[None] * xr
        keep = keep[:, None, None]
        nxr = jnp.where(keep, nxr, xr)
        nxi = jnp.where(keep, nxi, xi)
        return (nxr, nxi), (xr, xi)       # emit the state BEFORE the token

    (nxr, nxi), (pre_re, pre_im) = jax.lax.scan(
        body, (cache["x_re"], cache["x_im"]),
        (jnp.moveaxis(u, 1, 0), jnp.moveaxis(valid, 1, 0)), unroll=C)
    # batched Prop.-3.3 readout over all C positions at once
    y = jnp.einsum("cbed,ed->bce", pre_re, R_re) \
        - jnp.einsum("cbed,ed->bce", pre_im, R_im) + h0 * u
    out = (q.astype(jnp.float32) * y).astype(x.dtype)
    new_cache = {"conv": new_tail, "x_re": nxr, "x_im": nxi}
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    if return_states:
        # trajectory AFTER each token j (j = 1..C): positions 1..C-1 come
        # from the emitted pre-token states, position C from the final carry
        xs_re = jnp.concatenate([jnp.moveaxis(pre_re[1:], 0, 1),
                                 nxr[:, None]], axis=1)   # (B, C, D, d)
        xs_im = jnp.concatenate([jnp.moveaxis(pre_im[1:], 0, 1),
                                 nxi[:, None]], axis=1)
        aux = {"xs_re": xs_re, "xs_im": xs_im, "ext": ext}
        return new_cache, out, aux
    return new_cache, out


def hyena_decode_cached_conv_chunk(params, cache, x, pos, active_len, cfg,
                                   filters, *, ctx: ShardCtx = NOCTX):
    """Cached-conv (Lemma 2.1) multi-token decode: write up to C new k.v
    products per slot at positions [pos_b, pos_b + active_len_b) and emit the
    exact causal convolution with the TRUE long filter at every chunk
    position. x: (B, C, D); pos/active_len: (B,)."""
    B, C, D = x.shape
    h_full, h0 = filters                                  # (M, Lmax'), (M,)
    M = h_full.shape[0]
    qkv = jnp.einsum("bsd,dge->bsge", x,
                     params["wqkv"].astype(x.dtype)).reshape(B, C, 3 * D)
    pos = jnp.asarray(pos, jnp.int32)
    active_len = jnp.asarray(active_len, jnp.int32)
    new_tail, qkv, _ = _short_conv_rows(params["short_conv"],
                                        cache["conv"], qkv, active_len)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    kvc = (k * v).astype(cache["kv"].dtype)               # (B, C, D)
    Lmax = cache["kv"].shape[1]
    positions = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    widx = jnp.clip(positions, 0, Lmax - 1)
    valid = jnp.arange(C)[None, :] < active_len[:, None]
    b = jnp.arange(B)[:, None]
    cur = jnp.take_along_axis(cache["kv"],
                              jnp.broadcast_to(widx[..., None], (B, C, D)),
                              axis=1)
    kv_cache = cache["kv"].at[b, widx].set(
        jnp.where(valid[..., None], kvc, cur))
    # h_rev[b, c, j] = h[pos_b + c - j] for j <= pos_b + c else 0
    idx = positions[:, :, None] - jnp.arange(Lmax)[None, None, :]  # (B,C,L)
    hm = jnp.take(h_full, jnp.clip(idx, 0), axis=1)       # (M, B, C, Lmax)
    hr = jnp.where((idx >= 0)[None], hm, 0.0)
    hr = jnp.repeat(hr, D // M, axis=0)                   # (D, B, C, Lmax)
    y = jnp.einsum("bld,dbcl->bcd", kv_cache, hr.astype(kv_cache.dtype))
    y = y.astype(jnp.float32) + jnp.repeat(h0, D // M) * kvc.astype(jnp.float32)
    out = (q.astype(jnp.float32) * y).astype(x.dtype)
    new_cache = {"conv": new_tail, "kv": kv_cache}
    return new_cache, jnp.einsum("bse,ed->bsd", out,
                                 params["wo"].astype(x.dtype))


def hyena_decode_epoch_chunk(params, cache, x, pos, active_len, cfg,
                             filters, *, ctx: ShardCtx = NOCTX):
    """Epoched multi-token decode (speculative verify / replay): write up to
    C new k.v products per slot and emit the exact causal conv at every chunk
    position as fut[t] + an online tail of at most E + C terms — the at-rest
    tail is <= E by the flush invariant and the chunk adds <= C, so a widened
    static window covers every mid-chunk position without flushing.

    Two lax.cond flushes bracket the chunk: an ENTRY flush for slots whose
    at-rest tail exceeds E (a freshly admitted slot arrives with epoch 0 —
    prefill defers its flush to the first decode; the entry kv rows past pos
    are zero, so the full causal FFT is the prefix contribution), and an END
    flush restoring the <= E invariant for the next tick. `fut`/`epoch` are
    rewritten wholesale by flushes, which is why they are deliberately NOT
    in model._SEQ_KEYS: a speculative snapshot/rollback restores them whole
    while `kv` rolls back row-indexed."""
    B, C, D = x.shape
    h_full, h0 = filters                                  # (M, Lmax), (M,)
    M = h_full.shape[0]
    Lmax = cache["kv"].shape[1]
    E = epoch_tail(Lmax)
    W = min(E + C, Lmax)
    qkv = jnp.einsum("bsd,dge->bsge", x,
                     params["wqkv"].astype(x.dtype)).reshape(B, C, 3 * D)
    pos = jnp.asarray(pos, jnp.int32)
    active_len = jnp.asarray(active_len, jnp.int32)
    new_tail, qkv, _ = _short_conv_rows(params["short_conv"],
                                        cache["conv"], qkv, active_len)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    kvc = (k * v).astype(cache["kv"].dtype)               # (B, C, D)
    epoch = jnp.asarray(cache["epoch"], jnp.int32)
    entry = (pos - epoch) > E

    def do_entry(fut):
        full = fft_conv(cache["kv"], h_full).astype(fut.dtype)
        return jnp.where(entry[:, None, None], full, fut)

    fut = jax.lax.cond(jnp.any(entry), do_entry, lambda f: f, cache["fut"])
    epoch = jnp.where(entry, pos, epoch)
    positions = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    widx = jnp.clip(positions, 0, Lmax - 1)
    valid = jnp.arange(C)[None, :] < active_len[:, None]
    b = jnp.arange(B)[:, None]
    cur = jnp.take_along_axis(cache["kv"],
                              jnp.broadcast_to(widx[..., None], (B, C, D)),
                              axis=1)
    kv_cache = cache["kv"].at[b, widx].set(
        jnp.where(valid[..., None], kvc, cur))
    # per-position online tail over [epoch, pos_b + c]: <= E + C terms
    idx = positions[:, :, None] - jnp.arange(W)[None, None, :]   # (B, C, W)
    keep = (idx >= epoch[:, None, None]) & (idx >= 0)
    kv_g = jnp.take_along_axis(
        kv_cache, jnp.clip(idx, 0).reshape(B, C * W)[..., None],
        axis=1).reshape(B, C, W, D)
    h_tail = jnp.repeat(h_full[:, :W], D // M, axis=0)           # (D, W)
    y = jnp.einsum("bckd,dk->bcd", jnp.where(keep[..., None], kv_g, 0),
                   h_tail.astype(kv_cache.dtype))
    fut_c = jnp.take_along_axis(fut,
                                jnp.broadcast_to(widx[..., None], (B, C, D)),
                                axis=1)
    y = y.astype(jnp.float32) + fut_c.astype(jnp.float32) + \
        jnp.repeat(h0, D // M) * kvc.astype(jnp.float32)
    out = (q.astype(jnp.float32) * y).astype(x.dtype)
    # end-of-chunk flush keeps the at-rest tail <= E for the next tick
    new_pos = pos + active_len
    flush = (new_pos - epoch) > E

    def do_flush(fut):
        full = fft_conv(kv_cache, h_full).astype(fut.dtype)
        return jnp.where(flush[:, None, None], full, fut)

    fut = jax.lax.cond(jnp.any(flush), do_flush, lambda f: f, fut)
    new_epoch = jnp.where(flush, new_pos, epoch)
    new_cache = {"conv": new_tail, "kv": kv_cache, "fut": fut,
                 "epoch": new_epoch}
    return new_cache, jnp.einsum("bse,ed->bsd", out,
                                 params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Chunked (resumable) prefill: one fixed-size chunk of the prompt at a time
# ---------------------------------------------------------------------------
def hyena_prefill_chunk(params, cache, x, start, chunk_len, cfg, filters,
                        *, ctx: ShardCtx = NOCTX, cache_kind: str = "native"):
    """Consume one prompt chunk x (B, C, D) starting at absolute position
    `start` (traced scalar). The cache carries the short-conv tail AND the
    k.v product history buffer (B, Lbuf, D): the chunk's layer output is the
    exact causal convolution of the full history with the TRUE long filter
    (one fft over the zero-padded buffer — a single executable for any
    prompt length), so chunked prefill matches one-shot prefill, not the
    distilled approximation. For the "native" kind the distilled modal state
    is additionally advanced per chunk with the Sec.-3.4 update
    x <- lam^cl x + sum_{i<cl} lam^{cl-1-i} u_i (the per-chunk Vandermonde
    form of core/prefill.py). `chunk_len` <= C marks the real positions of a
    padded final chunk; positions past it write zeros and leave all state
    untouched.
    """
    B, C, D = x.shape
    h_full, h0 = filters                                   # (M, Lbuf'), (M,)
    M = h_full.shape[0]
    qkv = jnp.einsum("bsd,dge->bsge", x, params["wqkv"].astype(x.dtype))
    qkv = qkv.reshape(B, C, 3 * D)
    new_tail, qkv = short_conv_chunk(params["short_conv"], cache["conv"], qkv,
                                     chunk_len)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    valid = (jnp.arange(C) < chunk_len)[None, :, None]
    kvc = jnp.where(valid, (k * v), 0).astype(cache["kv"].dtype)
    kv_buf = jax.lax.dynamic_update_slice_in_dim(cache["kv"], kvc, start,
                                                 axis=1)
    y = jax.lax.dynamic_slice_in_dim(fft_conv(kv_buf, h_full), start, C,
                                     axis=1)
    y = y + kvc * jnp.repeat(h0, D // M)
    out = (q.astype(jnp.float32) * y).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    new_cache = {"conv": new_tail.astype(cache["conv"].dtype), "kv": kv_buf}
    if cache_kind != "conv":
        dp = params["distilled"]
        N = D // dp["log_a"].shape[0]
        cl = jnp.asarray(chunk_len, jnp.float32)
        # decay the incoming state by lam^cl ...
        scale = jnp.exp(dp["log_a"] * cl)                  # (M, d)
        lr = jnp.repeat(scale * jnp.cos(dp["theta"] * cl), N, axis=0)
        li = jnp.repeat(scale * jnp.sin(dp["theta"] * cl), N, axis=0)
        # ... and add the chunk's own Vandermonde contribution
        vr, vi = modal_prefill_state(dp, kvc, cfg.hyena,
                                     lengths=jnp.full((B,), chunk_len,
                                                      jnp.int32))
        xr, xi = cache["x_re"], cache["x_im"]
        new_cache["x_re"] = lr * xr - li * xi + vr
        new_cache["x_im"] = lr * xi + li * xr + vi
    return new_cache, out


# ---------------------------------------------------------------------------
# Literal Sec.-4 outer-product operator (for Theorem 4.1 validation)
# ---------------------------------------------------------------------------
def outer_product_op(q, k, v, h, M: int):
    """q,k,v: (B, L, D); h: (M, L). Returns (B, L, D).

    y^m_t = (h^m * (k^m (x) v^m))_t q^m_t  — O(L log L * M * N^2) via FFT.
    Only intended for small widths (tests / associative recall).
    """
    B, L, D = q.shape
    N = D // M
    qh = q.reshape(B, L, M, N)
    kh = k.reshape(B, L, M, N)
    vh = v.reshape(B, L, M, N)
    z = jnp.einsum("blmi,blmj->blmij", kh, vh).reshape(B, L, M * N * N)
    hz = fft_conv(z, jnp.repeat(h, N * N, axis=0)).reshape(B, L, M, N, N)
    y = jnp.einsum("blmij,blmj->blmi", hz, qh)
    return y.reshape(B, L, D)
