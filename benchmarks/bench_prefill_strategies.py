"""Sec. 3.4: fast pre-filling strategies — recurrent O(dT), parallel scan
O(d log T), Vandermonde matmul O(dT, MXU), FFT O~(T) (Prop. 3.2)."""
import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import (init_modal, prefill_fft, prefill_recurrent,
                        prefill_scan, prefill_vandermonde)

CH, D_MODES = 128, 8


def main(out):
    ssm = init_modal(jax.random.PRNGKey(0), (CH,), D_MODES,
                     r_minmax=(0.5, 0.95))
    for T in (512, 4096, 16384):
        u = jax.random.normal(jax.random.PRNGKey(1), (CH, T))
        for name, fn in (("recurrent", prefill_recurrent),
                         ("scan", prefill_scan),
                         ("vandermonde", prefill_vandermonde),
                         ("fft", prefill_fft)):
            jfn = jax.jit(fn)
            dt = timeit(jfn, ssm, u, warmup=1, iters=3)
            out(row(f"sec3.4/prefill_{name}/T{T}", dt * 1e6,
                    f"us_per_tok={dt*1e6/T:.2f}"))
