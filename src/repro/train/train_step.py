"""Distributed train step: fwd+bwd+AdamW under pjit, with optional gradient
accumulation (microbatching) and int8 gradient compression for the data-
parallel all-reduce."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, TRAIN_RULES
from repro.models.layers import ShardCtx
from repro.models.model import train_loss
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, cosine_schedule


def make_train_step(cfg: ModelConfig, mesh=None, *, rules: ShardingRules = TRAIN_RULES,
                    base_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000, moe_impl: str = "dropless",
                    remat: str = "full", accum: int = 1,
                    grad_compression: bool = False) -> Callable:
    """Returns train_step(params, opt_state, batch, step) -> (params, opt, metrics).

    Gradient accumulation runs `accum` microbatch fwd+bwd passes in a scan
    before the optimizer update — the standard way to overlap the DP gradient
    all-reduce with compute is to let XLA schedule the (reduced precision)
    accumulation loop; we additionally expose int8 compression of the final
    gradient as a collective-volume lever (error feedback is unnecessary for
    a single compression point per step).
    """
    ctx = ShardCtx(mesh=mesh, rules=rules)
    sched = cosine_schedule(base_lr, warmup, total_steps)

    def loss_fn(params, batch):
        return train_loss(params, batch, cfg, ctx=ctx, moe_impl=moe_impl,
                          remat=remat)

    def compress(g):
        """int8 stochastic-free symmetric quantization (per-leaf scale)."""
        def q(x):
            s = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
            return (jnp.round(x / s).astype(jnp.int8), s)
        return jax.tree.map(q, g)

    def decompress(gq):
        return jax.tree.map(lambda t: t[0].astype(jnp.float32) * t[1], gq,
                            is_leaf=lambda x: isinstance(x, tuple))

    def train_step(params, opt_state: AdamWState, batch, step):
        if accum > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def micro(acc, b):
                (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, b)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (loss, metrics)

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricss) = jax.lax.scan(micro, zero, mb)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricss)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        if grad_compression:
            grads = decompress(compress(grads))
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             lr=sched(step))
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def init_opt(params) -> AdamWState:
    return adamw_init(params)
