"""Pallas TPU kernel: blocked causal GQA flash attention.

Grid: (B * Hq, nq, nk) — the kv axis is the innermost (sequential on TPU) so
the online-softmax running statistics (m, l, acc) can live in VMEM scratch
across kv iterations. Block shapes are MXU-aligned: (qb, hd) x (kb, hd) with
qb, kb multiples of 128 and hd in {64, 128, 256}.

GQA is handled in the index maps: head h of q reads kv head h // G — no
repeat/materialization of k/v.

Causal skip: programs with block_j * kb > block_i * qb + qb - 1 write nothing
and skip the matmuls under pl.when (the grid still visits them; on TPU the
dominant cost — the MXU work — is gated off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU-only helpers; fall back for interpret mode on CPU
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            qb: int, kb: int, causal: bool, window: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    lo = qi * qb
    hi = lo + qb - 1
    needed = jnp.asarray(True)
    if causal:
        needed = needed & (kj * kb <= hi)
    if window > 0:
        needed = needed & ((kj + 1) * kb - 1 > lo - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # (qb, hd)
        k = k_ref[0].astype(jnp.float32)                   # (kb, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        qpos = lo + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
        kpos = kj * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        valid = jnp.ones((qb, kb), jnp.bool_)
        if causal:
            valid = valid & (kpos <= qpos)
        if window > 0:
            valid = valid & (kpos > qpos - window)
        s = jnp.where(valid, s, -jnp.inf)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(valid, jnp.exp(s - m_safe[:, None]), 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...][:, None], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "qb", "kb", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           qb: int = 128, kb: int = 128,
                           interpret: bool = True):
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qb = min(qb, S)
    kb = min(kb, T)
    assert S % qb == 0 and T % kb == 0
    nq, nk = S // qb, T // kb
    grid = (B * Hq, nq, nk)
    scale = 1.0 / np.sqrt(hd)

    # layouts: fold (B, H) into the grid; blocks are (1, qb|kb, hd)
    qt = jnp.moveaxis(q, 2, 1).reshape(B * Hq, S, hd)
    kt = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, T, hd)
    vt = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, T, hd)

    def q_map(bh, qi, kj):
        return (bh, qi, 0)

    def kv_map(bh, qi, kj):
        b = bh // Hq
        h = bh % Hq
        return (b * Hkv + h // G, kj, 0)

    scratch_shapes = None
    kwargs = {}
    if _VMEM is not None:
        kwargs["scratch_shapes"] = [
            _VMEM((qb,), jnp.float32),
            _VMEM((qb,), jnp.float32),
            _VMEM((qb, hd), jnp.float32),
        ]
    else:  # pragma: no cover
        from jax.experimental.pallas import MemorySpace
        kwargs["scratch_shapes"] = [
            pl.MemoryRef((qb,), jnp.float32),
            pl.MemoryRef((qb,), jnp.float32),
            pl.MemoryRef((qb, hd), jnp.float32),
        ]

    out = pl.pallas_call(
        functools.partial(_kernel, qb=qb, kb=kb, causal=causal, window=window,
                          scale=scale),
        grid=grid,
        in_specs=[pl.BlockSpec((1, qb, hd), q_map),
                  pl.BlockSpec((1, kb, hd), kv_map),
                  pl.BlockSpec((1, kb, hd), kv_map)],
        out_specs=pl.BlockSpec((1, qb, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, hd), q.dtype),
        interpret=interpret,
        **kwargs,
    )(qt, kt, vt)
    return jnp.moveaxis(out.reshape(B, Hq, S, hd), 1, 2)
