"""Top-level model: init / forward / train_loss / prefill / decode_step.

Every architecture in the pool is an instance of this assembly:
  embed -> [scan over pattern groups of blocks] -> remainder blocks -> norm -> logits
with optional encoder (whisper) and modality-frontend stubs (qwen2-vl audio).

Layer stacking: parameters of one pattern period ("group") are initialized per
group and stacked on a leading axis, then consumed by jax.lax.scan — keeping
HLO size O(pattern) instead of O(n_layers), which matters when compiling
80-layer models for 512 devices.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, HYENA, LOCAL_ATTN, MAMBA2, MLP_MOE,
                                RGLRU, ModelConfig)
from repro.distributed.sharding import Param
from repro.models import attention as attn_mod
from repro.models import hyena as hyena_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (NOCTX, ShardCtx, apply_mlp, apply_norm,
                                 embed_tokens, init_embed, init_mlp, init_norm,
                                 unembed)

is_param = lambda x: isinstance(x, Param)


def stack_groups(groups):
    """Stack a list of Param trees along a new leading (layer) axis."""
    def stack(*ps):
        return Param(jnp.stack([p.value for p in ps]), (None,) + tuple(ps[0].axes))
    return jax.tree.map(stack, *groups, is_leaf=is_param)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(key, kind: str, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": init_norm(cfg.norm, cfg.d_model)}
    if kind in (ATTN, LOCAL_ATTN):
        p["mix"] = attn_mod.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.hd)
    elif kind == HYENA:
        p["mix"] = hyena_mod.init_hyena_block(ks[0], cfg)
    elif kind == MAMBA2:
        p["mix"] = ssm_mod.init_mamba2_block(ks[0], cfg)
    elif kind == RGLRU:
        p["mix"] = ssm_mod.init_rglru_block(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["cross_norm"] = init_norm(cfg.norm, cfg.d_model)
        p["cross"] = attn_mod.init_attention(ks[1], cfg.d_model, cfg.n_heads,
                                             cfg.n_kv_heads, cfg.hd)
    if cfg.d_ff > 0:
        p["norm2"] = init_norm(cfg.norm, cfg.d_model)
        if cfg.mlp_kind == MLP_MOE:
            p["mlp"] = moe_mod.init_moe(ks[2], cfg.d_model, cfg.d_ff, cfg.moe)
        else:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act)
    return p


def _init_group(key, cfg: ModelConfig, cross: bool):
    ks = jax.random.split(key, len(cfg.pattern))
    return {f"l{i}": _init_block(ks[i], kind, cfg, cross)
            for i, kind in enumerate(cfg.pattern)}


def layer_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups scanned, n_remainder unstacked layers)."""
    period = len(cfg.pattern)
    return cfg.n_layers // period, cfg.n_layers % period


def init_params(key, cfg: ModelConfig):
    """Returns a Param tree (values + logical axes)."""
    n_groups, n_rem = layer_layout(cfg)
    keys = jax.random.split(key, n_groups + n_rem + 4)
    cross = cfg.enc_dec
    groups = [_init_group(keys[i], cfg, cross) for i in range(n_groups)]
    params: Dict[str, Any] = {
        "embed": init_embed(keys[-1], cfg.vocab, cfg.d_model, cfg.tie_embeddings,
                            max_seq=max(cfg.max_seq, 1),
                            learned_pos=(cfg.rope_theta <= 0.0)),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
        "groups": stack_groups(groups),
    }
    if n_rem:
        params["rem"] = [
            _init_block(keys[n_groups + i], cfg.blocks[n_groups * len(cfg.pattern) + i],
                        cfg, cross)
            for i in range(n_rem)
        ]
    if cfg.enc_dec:
        ekeys = jax.random.split(keys[-2], cfg.n_enc_layers)
        enc = [_init_block(ekeys[i], ATTN, cfg, cross=False)
               for i in range(cfg.n_enc_layers)]
        params["encoder"] = stack_groups(enc)
        params["enc_norm"] = init_norm(cfg.norm, cfg.d_model)
        params["enc_pos"] = Param(
            jax.random.normal(keys[-3], (cfg.frontend_len, cfg.d_model)) * 0.02,
            (None, "embed"))
    return params


# ---------------------------------------------------------------------------
# Forward (full sequence)
# ---------------------------------------------------------------------------
def _apply_block(bp, kind: str, x, positions, cfg: ModelConfig, ctx: ShardCtx,
                 *, enc_out=None, moe_impl: str, collect_cache: bool = False,
                 cross_kv_cache=None, cache_kind: str = "native"):
    """One block (mix + mlp). Returns (x, aux_loss, cache_or_None)."""
    h = apply_norm(bp["norm1"], x, cfg.norm)
    cache = None
    window = cfg.window if kind == LOCAL_ATTN else 0
    if kind in (ATTN, LOCAL_ATTN):
        if collect_cache:
            y, (k, v) = attn_mod.attention_block(
                bp["mix"], h, positions, cfg, window=window, ctx=ctx, return_kv=True)
            cache = {"k": k, "v": v}
        else:
            y = attn_mod.attention_block(bp["mix"], h, positions, cfg,
                                         window=window, ctx=ctx)
    elif kind == HYENA:
        if collect_cache:
            y, cache = hyena_mod.hyena_block(bp["mix"], h, cfg, ctx=ctx,
                                             return_cache=True,
                                             cache_kind=cache_kind)
        else:
            y = hyena_mod.hyena_block(bp["mix"], h, cfg, ctx=ctx)
    elif kind == MAMBA2:
        if collect_cache:
            y, cache = ssm_mod.mamba2_block(bp["mix"], h, cfg, ctx=ctx,
                                            return_state=True)
        else:
            y = ssm_mod.mamba2_block(bp["mix"], h, cfg, ctx=ctx)
    elif kind == RGLRU:
        if collect_cache:
            y, cache = ssm_mod.rglru_block(bp["mix"], h, cfg, ctx=ctx,
                                           return_state=True)
        else:
            y = ssm_mod.rglru_block(bp["mix"], h, cfg, ctx=ctx)
    else:
        raise ValueError(kind)
    x = ctx.cs(x + y, ("batch", None, "act_embed"))
    if "cross" in bp:
        h = apply_norm(bp["cross_norm"], x, cfg.norm)
        if cross_kv_cache is not None:
            kv = cross_kv_cache
        else:
            assert enc_out is not None
            kv = attn_mod.compute_kv(bp["cross"], enc_out, None, cfg)
        y = attn_mod.attention_block(bp["cross"], h, positions, cfg, ctx=ctx,
                                     cross_kv=kv)
        x = x + y
        if collect_cache and cache is not None:
            cache["cross_k"], cache["cross_v"] = kv
        elif collect_cache:
            cache = {"cross_k": kv[0], "cross_v": kv[1]}
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        h = apply_norm(bp["norm2"], x, cfg.norm)
        if cfg.mlp_kind == MLP_MOE:
            y, aux = moe_mod.moe_block(bp["mlp"], h, cfg.moe, impl=moe_impl, ctx=ctx)
        else:
            y = apply_mlp(bp["mlp"], h, cfg.act, ctx=ctx)
        x = ctx.cs(x + y, ("batch", None, "act_embed"))
    return x, aux, cache


def forward(params, tokens, cfg: ModelConfig, *, ctx: ShardCtx = NOCTX,
            frontend: Optional[jnp.ndarray] = None, moe_impl: str = "dropless",
            remat: Optional[str] = "none", collect_cache: bool = False,
            cache_kind: str = "native"):
    """Full-sequence forward. tokens: (B, S) int32.

    Returns logits (B, S', vocab) and, with collect_cache, the per-layer
    decode caches (for prefill). For VLM, `frontend` embeddings are prepended
    (S' includes them). For enc-dec, `frontend` feeds the encoder.
    cache_kind: "native" (recurrent/kv states) or "conv" (Hyena layers cache
    the k.v product sequence for the Lemma-2.1 cached-conv baseline).
    """
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = embed_tokens(params["embed"], tokens, ctx=ctx, dtype=dtype)
    enc_out = None
    if cfg.enc_dec and frontend is not None:
        enc_out = encode_stack(params, frontend.astype(dtype), cfg, ctx)
    elif frontend is not None:                       # VLM: prepend patch embeds
        x = jnp.concatenate([frontend.astype(dtype), x], axis=1)
    if cfg.rope_theta <= 0.0:                        # learned absolute positions
        x = x + params["embed"]["pos"][None, :x.shape[1], :].astype(dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :],
                                 (x.shape[0], x.shape[1]))

    n_groups, n_rem = layer_layout(cfg)

    def group_body(carry, gp):
        x, aux = carry
        caches = {}
        for i, kind in enumerate(cfg.pattern):
            x, a, c = _apply_block(gp[f"l{i}"], kind, x, positions, cfg, ctx,
                                   enc_out=enc_out, moe_impl=moe_impl,
                                   collect_cache=collect_cache,
                                   cache_kind=cache_kind)
            aux = aux + a
            if collect_cache:
                caches[f"l{i}"] = c
        return (x, aux), (caches if collect_cache else None)

    body = group_body
    if remat and remat != "none":
        policy = {
            "full": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.checkpoint_dots,
            "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        }[remat]
        body = jax.checkpoint(group_body, policy=policy)

    from repro import flags
    n_g = jax.tree.leaves(params["groups"])[0].shape[0]
    (x, aux), scan_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                         params["groups"],
                                         unroll=flags.scan_unroll(n_g))
    rem_caches = []
    for i in range(n_rem):
        kind = cfg.blocks[n_groups * len(cfg.pattern) + i]
        x, a, c = _apply_block(params["rem"][i], kind, x, positions, cfg, ctx,
                               enc_out=enc_out, moe_impl=moe_impl,
                               collect_cache=collect_cache,
                               cache_kind=cache_kind)
        aux = aux + a
        rem_caches.append(c)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     softcap=cfg.logit_softcap, ctx=ctx)
    if collect_cache:
        return logits, aux, (scan_caches, rem_caches)
    return logits, aux


def encode_stack(params, frontend_emb, cfg: ModelConfig, ctx: ShardCtx):
    x = frontend_emb + params["enc_pos"][None, :frontend_emb.shape[1], :].astype(
        frontend_emb.dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :],
                                 (x.shape[0], x.shape[1]))

    def body(carry, lp):
        h = apply_norm(lp["norm1"], carry, cfg.norm)
        y = attn_mod.attention_block(lp["mix"], h, positions, cfg, ctx=ctx,
                                     causal=False)
        carry = carry + y
        h = apply_norm(lp["norm2"], carry, cfg.norm)
        carry = carry + apply_mlp(lp["mlp"], h, cfg.act, ctx=ctx)
        return carry, None

    from repro import flags
    n_e = jax.tree.leaves(params["encoder"])[0].shape[0]
    x, _ = jax.lax.scan(body, x, params["encoder"], unroll=flags.scan_unroll(n_e))
    return apply_norm(params["enc_norm"], x, cfg.norm)


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------
def train_loss(params, batch, cfg: ModelConfig, *, ctx: ShardCtx = NOCTX,
               moe_impl: str = "dropless", remat: str = "none"):
    """batch: {tokens (B,S), [frontend]}. Next-token cross-entropy."""
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens[:, :-1], cfg, ctx=ctx,
                          frontend=batch.get("frontend"), moe_impl=moe_impl,
                          remat=remat)
    targets = tokens[:, 1:]
    if logits.shape[1] != targets.shape[1]:          # VLM prepended frontend
        logits = logits[:, -targets.shape[1]:, :]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def _init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                      cross: bool, cache_kind: str = "native"):
    c: Dict[str, Any] = {}
    if kind in (ATTN, LOCAL_ATTN):
        eff = max_len if kind == ATTN or cfg.window <= 0 else min(max_len, cfg.window)
        kv = attn_mod.init_kv_cache(batch, eff, cfg.n_kv_heads, cfg.hd)
        c["k"] = Param(kv["k"], ("batch", "kv_seq", "kv_heads", None))
        c["v"] = Param(kv["v"], ("batch", "kv_seq", "kv_heads", None))
        if eff < max_len:                       # ring buffer for windowed layers
            c["slot_pos"] = Param(jnp.full((batch, eff), -1, jnp.int32),
                                  ("batch", None))
    elif kind == HYENA and cache_kind == "conv":
        hc = hyena_mod.init_hyena_conv_cache(batch, max_len, cfg)
        c["conv"] = Param(hc["conv"], ("batch", None, "qkv"))
        c["kv"] = Param(hc["kv"], ("batch", "kv_seq", "qkv"))
    elif kind == HYENA:
        hc = hyena_mod.init_hyena_cache(batch, cfg)
        c["conv"] = Param(hc["conv"], ("batch", None, "qkv"))
        c["x_re"] = Param(hc["x_re"], ("batch", "qkv", "state"))
        c["x_im"] = Param(hc["x_im"], ("batch", "qkv", "state"))
    elif kind == MAMBA2:
        mc = ssm_mod.init_mamba2_cache(batch, cfg)
        c["conv"] = Param(mc["conv"], ("batch", None, "mlp"))
        c["ssm"] = Param(mc["ssm"], ("batch", "heads", None, "state"))
    elif kind == RGLRU:
        rc = ssm_mod.init_rglru_cache(batch, cfg)
        c["conv"] = Param(rc["conv"], ("batch", None, "mlp"))
        c["h"] = Param(rc["h"], ("batch", "mlp"))
    if cross:
        F = cfg.frontend_len
        c["cross_k"] = Param(jnp.zeros((batch, F, cfg.n_kv_heads, cfg.hd),
                                       jnp.bfloat16),
                             ("batch", "kv_seq", "kv_heads", None))
        c["cross_v"] = Param(jnp.zeros((batch, F, cfg.n_kv_heads, cfg.hd),
                                       jnp.bfloat16),
                             ("batch", "kv_seq", "kv_heads", None))
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               cache_kind: str = "native", per_slot: bool = False):
    """Param-tree of decode caches (leading group axis on scanned layers).

    per_slot=True gives each batch row its own position counter (B,) — the
    layout the continuous-batching engine uses, where every slot holds an
    independent request at its own decode position.
    """
    n_groups, n_rem = layer_layout(cfg)
    group = {f"l{i}": _init_block_cache(kind, cfg, batch, max_len, cfg.enc_dec,
                                        cache_kind)
             for i, kind in enumerate(cfg.pattern)}
    stacked = jax.tree.map(
        lambda p: Param(jnp.broadcast_to(p.value, (n_groups,) + p.value.shape),
                        (None,) + tuple(p.axes)),
        group, is_leaf=is_param)
    pos = (Param(jnp.zeros((batch,), jnp.int32), ("batch",)) if per_slot
           else Param(jnp.zeros((), jnp.int32), ()))
    cache: Dict[str, Any] = {"groups": stacked, "pos": pos}
    if n_rem:
        cache["rem"] = [
            _init_block_cache(cfg.blocks[n_groups * len(cfg.pattern) + i], cfg,
                              batch, max_len, cfg.enc_dec, cache_kind)
            for i in range(n_rem)
        ]
    return cache


def _decode_block(bp, bc, kind: str, x, pos, cfg: ModelConfig, ctx: ShardCtx,
                  conv_filters=None):
    h = apply_norm(bp["norm1"], x, cfg.norm)
    window = cfg.window if kind == LOCAL_ATTN else 0
    if kind in (ATTN, LOCAL_ATTN):
        kv = {k: bc[k] for k in ("k", "v", "slot_pos") if k in bc}
        kv, y = attn_mod.attention_decode(bp["mix"], kv, h, pos, cfg,
                                          window=window, ctx=ctx)
        bc = dict(bc, **kv)
    elif kind == HYENA:
        if "kv" in bc:            # Lemma-2.1 cached-conv baseline (O(t)/token)
            sub = {k: bc[k] for k in ("conv", "kv")}
            if conv_filters is None:   # fallback: re-materialize every step
                conv_filters = hyena_mod.materialize_filters(
                    bp["mix"]["filter"], bc["kv"].shape[1], cfg.hyena)
            sub, y = hyena_mod.hyena_decode_cached_conv(
                bp["mix"], sub, h, pos, cfg, conv_filters, ctx=ctx)
        else:                     # distilled modal recurrence (O(d)/token)
            sub = {k: bc[k] for k in ("conv", "x_re", "x_im")}
            sub, y = hyena_mod.hyena_decode(bp["mix"], sub, h, cfg, ctx=ctx)
        bc = dict(bc, **sub)
    elif kind == MAMBA2:
        sub = {k: bc[k] for k in ("conv", "ssm")}
        sub, y = ssm_mod.mamba2_decode(bp["mix"], sub, h, cfg, ctx=ctx)
        bc = dict(bc, **sub)
    elif kind == RGLRU:
        sub = {k: bc[k] for k in ("conv", "h")}
        sub, y = ssm_mod.rglru_decode(bp["mix"], sub, h, cfg, ctx=ctx)
        bc = dict(bc, **sub)
    else:
        raise ValueError(kind)
    x = x + y
    if "cross" in bp:
        h = apply_norm(bp["cross_norm"], x, cfg.norm)
        y = attn_mod.attention_block(bp["cross"], h,
                                     jnp.zeros((x.shape[0], 1), jnp.int32), cfg,
                                     ctx=ctx, cross_kv=(bc["cross_k"], bc["cross_v"]))
        x = x + y
    if cfg.d_ff > 0:
        h = apply_norm(bp["norm2"], x, cfg.norm)
        if cfg.mlp_kind == MLP_MOE:
            y, _ = moe_mod.moe_block(bp["mlp"], h, cfg.moe, ctx=ctx)
        else:
            y = apply_mlp(bp["mlp"], h, cfg.act, ctx=ctx)
        x = x + y
    return bc, x


def decode_step(params, cache, tokens, cfg: ModelConfig, *, ctx: ShardCtx = NOCTX,
                conv_filters=None):
    """One decode step. tokens: (B, 1) int32. Returns (cache, logits).

    cache["pos"] is either a scalar (uniform batch: every row at the same
    position) or a (B,) vector (continuous batching: one position per slot).
    conv_filters (from `materialize_conv_filters`) supplies pre-materialized
    long filters for cached-conv Hyena layers; without it each decode step
    re-runs the filter MLP (hot-loop waste — engines always pass it).
    """
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    pos = jnp.asarray(cache["pos"], jnp.int32)
    x = embed_tokens(params["embed"], tokens, ctx=ctx, dtype=dtype)
    if cfg.rope_theta <= 0.0:
        pe = params["embed"]["pos"]
        if pos.ndim == 1:
            x = x + jnp.take(pe, jnp.clip(pos, 0, pe.shape[0] - 1),
                             axis=0)[:, None, :].astype(dtype)
        else:
            x = x + jax.lax.dynamic_slice_in_dim(
                pe, pos, 1, axis=0)[None].astype(dtype)[:, 0:1]
    n_groups, n_rem = layer_layout(cfg)

    def body(x, gp_gc):
        gp, gc = gp_gc[0], gp_gc[1]
        gf = gp_gc[2] if len(gp_gc) > 2 else {}
        for i, kind in enumerate(cfg.pattern):
            gc[f"l{i}"], x = _decode_block(gp[f"l{i}"], gc[f"l{i}"], kind, x,
                                           pos, cfg, ctx,
                                           conv_filters=gf.get(f"l{i}"))
        return x, gc

    from repro import flags
    n_g = jax.tree.leaves(params["groups"])[0].shape[0]
    xs = (params["groups"], cache["groups"])
    if conv_filters is not None:
        xs = xs + (conv_filters["groups"],)
    x, new_group_caches = jax.lax.scan(body, x, xs,
                                       unroll=flags.scan_unroll(n_g))
    new_cache = {"groups": new_group_caches, "pos": pos + 1}
    if n_rem:
        rem_filters = (conv_filters or {}).get("rem", {})
        rem = []
        for i in range(n_rem):
            kind = cfg.blocks[n_groups * len(cfg.pattern) + i]
            bc, x = _decode_block(params["rem"][i], cache["rem"][i], kind, x,
                                  pos, cfg, ctx,
                                  conv_filters=rem_filters.get(i))
            rem.append(bc)
        new_cache["rem"] = rem
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     softcap=cfg.logit_softcap, ctx=ctx)
    return new_cache, logits


# ---------------------------------------------------------------------------
# Prefill: full-sequence pass that fills the decode caches
# ---------------------------------------------------------------------------
def prefill(params, tokens, cfg: ModelConfig, max_len: int, *,
            ctx: ShardCtx = NOCTX, frontend=None, moe_impl: str = "dropless",
            cache_kind: str = "native"):
    """Process prompt, return (cache, last_logits).

    Attention k/v from the forward pass are padded into max_len cache buffers;
    recurrent blocks produce O(1) states directly (Sec. 3.4 fast pre-filling).
    With cache_kind="conv", Hyena layers cache the k.v product sequence for
    the Lemma-2.1 cached-conv decode baseline instead of the modal state.
    """
    B, T = tokens.shape
    logits, _, (scan_caches, rem_caches) = forward(
        params, tokens, cfg, ctx=ctx, frontend=frontend, moe_impl=moe_impl,
        collect_cache=True, remat="none", cache_kind=cache_kind)
    if frontend is not None and not cfg.enc_dec:
        T = T + frontend.shape[1]              # VLM: patches occupy kv positions

    def to_ring(leaf, seq_axis: int, eff: int):
        """Reorder the last min(T,eff) positions into ring-slot order."""
        Tc = leaf.shape[seq_axis]
        if Tc <= eff:
            pad = [(0, 0)] * leaf.ndim
            pad[seq_axis] = (0, eff - Tc)
            ring = jnp.pad(leaf, pad)
            slot_pos = jnp.where(jnp.arange(eff) < Tc, jnp.arange(eff), -1)
        else:
            base = Tc - eff
            j = jnp.arange(eff)
            p = base + ((j - base) % eff)
            ring = jnp.take(leaf, p, axis=seq_axis)
            slot_pos = p
        return ring, slot_pos.astype(jnp.int32)

    def fix_cache(c, kind: str, seq_axis: int):
        eff = max_len
        if kind == LOCAL_ATTN and 0 < cfg.window < max_len:
            eff = cfg.window
        out = {}
        for k, v in c.items():
            if k in ("k", "v"):
                if eff < max_len:
                    ring, sp = to_ring(v.astype(jnp.bfloat16), seq_axis, eff)
                    out[k] = ring
                    # slot_pos is per batch row: (B, eff) / (n_groups, B, eff)
                    sp = jnp.broadcast_to(sp, v.shape[:seq_axis - 1] + (B, eff))
                    out["slot_pos"] = sp
                else:
                    pad = [(0, 0)] * v.ndim
                    pad[seq_axis] = (0, max_len - v.shape[seq_axis])
                    out[k] = jnp.pad(v.astype(jnp.bfloat16), pad)
            elif k == "kv":                    # hyena cached-conv kv products
                pad = [(0, 0)] * v.ndim
                pad[seq_axis] = (0, max_len - v.shape[seq_axis])
                out[k] = jnp.pad(v, pad)
            elif k in ("cross_k", "cross_v"):
                out[k] = v.astype(jnp.bfloat16)
            elif k != "slot_pos":
                out[k] = v
        return out

    groups = {lk: fix_cache(lv, cfg.pattern[int(lk[1:])], seq_axis=2)
              for lk, lv in scan_caches.items()}
    cache = {"groups": groups, "pos": jnp.asarray(T, jnp.int32)}
    n_groups, n_rem = layer_layout(cfg)
    if n_rem:
        cache["rem"] = [
            fix_cache(rc, cfg.blocks[n_groups * len(cfg.pattern) + i], seq_axis=1)
            for i, rc in enumerate(rem_caches)
        ]
    return cache, logits[:, -1, :]


def materialize_conv_filters(params, cfg: ModelConfig, max_len: int):
    """Pre-materialize every Hyena layer's long filters at max_len for the
    cached-conv decode path. One-time engine-setup cost; pass the result to
    `decode_step(conv_filters=...)` so the hot loop doesn't re-run the
    filter MLP each token. Layout mirrors the cache: {"groups": {l_i:
    (h (G,M,L), h0 (G,M))}, "rem": {i: (h, h0)}}."""
    hcfg = cfg.hyena
    n_groups, n_rem = layer_layout(cfg)
    out: Dict[str, Any] = {"groups": {}}
    for i, kind in enumerate(cfg.pattern):
        if kind == HYENA:
            out["groups"][f"l{i}"] = jax.vmap(
                lambda fp: hyena_mod.materialize_filters(fp, max_len, hcfg))(
                    params["groups"][f"l{i}"]["mix"]["filter"])
    rem = {}
    for i in range(n_rem):
        if cfg.blocks[n_groups * len(cfg.pattern) + i] == HYENA:
            rem[i] = hyena_mod.materialize_filters(
                params["rem"][i]["mix"]["filter"], max_len, hcfg)
    if rem:
        out["rem"] = rem
    return out


# ---------------------------------------------------------------------------
# Slot-indexed cache helpers (continuous-batching serving engine)
#
# A pooled cache (init_cache(..., per_slot=True)) holds one request per batch
# row ("slot"). Admission scatters a freshly prefilled batch=1 cache into a
# free slot; eviction just frees the slot — its stale state is fully
# overwritten on readmission (reset_cache_slot exists for explicit hygiene).
# ---------------------------------------------------------------------------
def _slot_update(axis: int, slot):
    def f(pool_leaf, single_leaf):
        return jax.lax.dynamic_update_slice_in_dim(
            pool_leaf, single_leaf.astype(pool_leaf.dtype), slot, axis=axis)
    return f


def write_cache_slot(pool, single, slot):
    """Scatter a batch=1 cache (from `prefill`) into row `slot` of a pooled
    per-slot cache. Group leaves carry a leading layer axis, so their batch
    axis is 1; remainder leaves and `pos` use axis 0. jit-friendly (traced
    `slot`)."""
    slot = jnp.asarray(slot, jnp.int32)
    out = {"groups": jax.tree.map(_slot_update(1, slot), pool["groups"],
                                  single["groups"]),
           "pos": pool["pos"].at[slot].set(
               jnp.asarray(single["pos"], jnp.int32))}
    if "rem" in pool:
        out["rem"] = jax.tree.map(_slot_update(0, slot), pool["rem"],
                                  single["rem"])
    return out


def reset_cache_slot(pool, slot):
    """Zero row `slot` of a pooled cache (ring slot_pos rows to -1, pos 0)."""
    from jax.tree_util import DictKey, tree_map_with_path
    slot = jnp.asarray(slot, jnp.int32)

    def rz(axis: int):
        def f(path, leaf):
            is_sp = any(isinstance(k, DictKey) and k.key == "slot_pos"
                        for k in path)
            row = jnp.full(leaf.shape[:axis] + (1,) + leaf.shape[axis + 1:],
                           -1 if is_sp else 0, leaf.dtype)
            return jax.lax.dynamic_update_slice_in_dim(leaf, row, slot,
                                                       axis=axis)
        return f

    out = {"groups": tree_map_with_path(rz(1), pool["groups"]),
           "pos": pool["pos"].at[slot].set(0)}
    if "rem" in pool:
        out["rem"] = tree_map_with_path(rz(0), pool["rem"])
    return out
