"""Distill a trained MultiHyena checkpoint and serve it recurrently,
reproducing the paper's order-sweep analysis (Sec. 5.2/5.3):

  PYTHONPATH=src python examples/distill_and_serve.py [--ckpt /tmp/multihyena_run]

For each distillation order d in {4, 8, 16, 32}:
  - distill all filters (modal interpolation, Kung-initialized AdamW)
  - report filter rel-l2 error and the relative logit error vs the
    convolutional forward (the paper's Fig. 5.1 criterion)
then serve the best order with the generation engine.
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from examples.train_multihyena import build_cfg
from repro.core.distill import distill_model
from repro.data.pipeline import SyntheticLM
from repro.distributed.sharding import unzip
from repro.models.model import decode_step, forward, init_params, prefill
from repro.serve.engine import GenerationEngine
from repro.train.checkpoint import Checkpointer
from repro.train.train_step import init_opt, make_train_step


def logit_error(cfg, params, toks, P):
    full, _ = forward(params, toks, cfg)
    cache, last = prefill(params, toks[:, :P], cfg, max_len=toks.shape[1])
    errs = [jnp.max(jnp.abs(last - full[:, P - 1]))]
    for t in range(P, toks.shape[1]):
        cache, lg = decode_step(params, cache, toks[:, t:t + 1], cfg)
        errs.append(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
    return float(max(errs)) / float(jnp.max(jnp.abs(full)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    cfg = build_cfg(128, 4, 512)
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    if args.ckpt:
        (params, _), step = Checkpointer(args.ckpt).restore((params, None))
        print(f"restored checkpoint step {step}")
    else:
        # quick pretrain so the filters are the trained (compressible) kind
        src = SyntheticLM(vocab=cfg.vocab, seq_len=128, global_batch=8, seed=0)
        opt = init_opt(params)
        stepf = jax.jit(make_train_step(cfg, None, base_lr=2e-3, warmup=10,
                                        total_steps=150, remat="none"))
        for i in range(150):
            params, opt, m = stepf(params, opt,
                                   {"tokens": jnp.asarray(src.batch(i))},
                                   jnp.asarray(i))
        print(f"pretrained 150 steps, loss {float(m['loss']):.3f}")

    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 64), 0, cfg.vocab)
    print(f"{'order':>6} {'worst filter rel-l2':>20} {'rel logit err':>14}")
    best = None
    for order in (4, 8, 16, 32):
        pd, errs = distill_model(params, cfg, d=order, steps=2000, L=512)
        worst = max(float(jnp.max(e)) for e in errs.values())
        lerr = logit_error(cfg, pd, toks, 56)
        print(f"{order:6d} {worst:20.4f} {lerr:14.4f}")
        if best is None or lerr < best[1]:
            best = (order, lerr, pd)

    order, lerr, pd = best
    print(f"\nserving with order {order} (rel logit err {lerr:.4f})")
    eng = GenerationEngine(pd, cfg, max_len=96)
    out, info = eng.generate(jax.random.PRNGKey(3), toks[:, :32], 16,
                             temperature=0.0)
    print("generated:", out[0].tolist())
    print(f"constant decode state: {info['cache_bytes']/1e3:.1f} KB")


if __name__ == "__main__":
    main()
