"""Auto-regressive generation engine (paper Sec. 2.2 / 3.4 / 5.4).

Drives prefill + decode for every architecture in the pool. For LCSMs the
engine exposes the paper's deployment modes:

  * "distilled"   — LaughingHyena recurrent mode: O(d) per token, O(d) state
  * "cached_conv" — Lemma 2.1 baseline: O(t) per token, O(L) kv-product cache
  * "epoch"       — FutureFill epoched convolution: exact output from the
                    TRUE long filter at amortized O(sqrt(L) log L) per token
  * (transformers use their native kv cache; SSM/hybrid their native state)

Both modes run through the same jitted `prefill` / `decode_step` pair — the
mode only selects which cache the Hyena layers carry (`cache_kind`). The
decode loop is a single jitted step re-invoked from Python; `generate_scanned`
provides a fully-jitted lax.scan loop for benchmarks. Multi-request serving
with per-slot state lives in `repro.serve.scheduler`.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import NOCTX, ShardCtx
from repro.models.model import (decode_step, finalize_prefill_cache,
                                materialize_conv_filters, prefill,
                                prefill_from_cache, slot_health)
from repro.serve.sampling import sample_token

# Shared jit memo: engines are cheap throwaway objects (tests/benchmarks
# build many), but functools.partial defeats jax's jit cache — so the jitted
# decode/prefill callables are memoized per (cfg, max_len, cache_kind, ctx)
# and shared across GenerationEngine and ContinuousBatchingEngine instances.
_JIT_CACHE: Dict = {}


def jitted_decode_step(cfg: ModelConfig, ctx: ShardCtx = NOCTX, *,
                       out_shardings=None, shard_key=None):
    """`out_shardings` pins the (cache, logits) output shardings for a
    sharded slot pool — the layout never drifts between ticks, so the
    steady state stays at zero recompiles. `shard_key` distinguishes the
    sharded executable from the single-device one in the shared memo."""
    key = ("decode", cfg, id(ctx), shard_key)
    if key not in _JIT_CACHE:
        kw = {} if out_shardings is None else {"out_shardings": out_shardings}
        _JIT_CACHE[key] = jax.jit(
            functools.partial(decode_step, cfg=cfg, ctx=ctx),
            donate_argnums=(1,), **kw)
    return _JIT_CACHE[key]


def _decode_step_guarded(params, cache, tokens, bound, *, cfg, ctx,
                         conv_filters=None):
    cache, logits = decode_step(params, cache, tokens, cfg=cfg, ctx=ctx,
                                conv_filters=conv_filters)
    return cache, logits, slot_health(cache, logits[:, 0, :], bound)


def jitted_decode_step_guarded(cfg: ModelConfig, ctx: ShardCtx = NOCTX, *,
                               out_shardings=None, shard_key=None):
    """Pooled decode step with the per-slot state-integrity reduction fused
    into the same executable (`bound` is data — one compile covers every
    margin). A separate jitted health call costs a whole extra host dispatch
    per tick, which on CPU is ~25% of saturated decode throughput; fused,
    the guard rides the decode dispatch for (nearly) free.
    `out_shardings`/`shard_key`: see `jitted_decode_step`."""
    key = ("decode_guarded", cfg, id(ctx), shard_key)
    if key not in _JIT_CACHE:
        kw = {} if out_shardings is None else {"out_shardings": out_shardings}
        _JIT_CACHE[key] = jax.jit(
            functools.partial(_decode_step_guarded, cfg=cfg, ctx=ctx),
            donate_argnums=(1,), **kw)
    return _JIT_CACHE[key]


def jitted_prefill(cfg: ModelConfig, max_len: int, cache_kind: str = "native",
                   ctx: ShardCtx = NOCTX):
    key = ("prefill", cfg, max_len, cache_kind, id(ctx))
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = jax.jit(
            functools.partial(prefill, cfg=cfg, max_len=max_len, ctx=ctx,
                              cache_kind=cache_kind))
    return _JIT_CACHE[key]


def jitted_prefill_chunk(cfg: ModelConfig, max_len: int,
                         cache_kind: str = "native", ctx: ShardCtx = NOCTX):
    """Resumable chunk step (prefill_from_cache): one executable per chunk
    shape, shared across engines. Call (params, pcache, tokens, start_pos,
    chunk_len=..., conv_filters=...); the scratch cache is donated."""
    key = ("prefill_chunk", cfg, max_len, cache_kind, id(ctx))
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = jax.jit(
            functools.partial(prefill_from_cache, cfg=cfg, max_len=max_len,
                              ctx=ctx, cache_kind=cache_kind),
            donate_argnums=(1,))
    return _JIT_CACHE[key]


def jitted_finalize_prefill(cfg: ModelConfig, max_len: int,
                            cache_kind: str = "native"):
    # no donation: the f32 scratch buffers cannot back the trimmed/bf16
    # decode-cache outputs, so donating them only produces warnings
    key = ("finalize_prefill", cfg, max_len, cache_kind)
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = jax.jit(
            functools.partial(finalize_prefill_cache, cfg=cfg,
                              max_len=max_len, cache_kind=cache_kind))
    return _JIT_CACHE[key]


class GenerationEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 4096,
                 ctx: ShardCtx = NOCTX, mode: str = "distilled",
                 tracer=None):
        if mode not in ("distilled", "cached_conv", "epoch"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode in ("cached_conv", "epoch") and cfg.hyena is None:
            raise ValueError(f"{mode} mode requires a Hyena (LCSM) arch")
        from repro.serve.trace import NULL_TRACER
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.ctx = ctx
        self.mode = mode
        self.cache_kind = {"distilled": "native", "cached_conv": "conv",
                           "epoch": "epoch"}[mode]
        self._decode = jitted_decode_step(cfg, ctx)
        self._prefill = jitted_prefill(cfg, max_len, self.cache_kind, ctx)
        # conv/epoch modes: materialize the long filters once, not per token
        self._conv_filters = (materialize_conv_filters(params, cfg, max_len)
                              if self.cache_kind in ("conv", "epoch")
                              else None)

    def generate(self, key, prompt: jnp.ndarray, n_tokens: int, *,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
                 frontend: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, Dict]:
        """prompt: (B, T) int32 -> (B, n_tokens) generated ids."""
        tr = self.tracer
        with tr.device_span("prefill", tokens=int(prompt.shape[-1])):
            cache, last_logits = self._prefill(self.params, prompt,
                                               frontend=frontend)
        toks = []
        logits = last_logits
        for i in range(n_tokens):
            key, sub = jax.random.split(key)
            nxt = sample_token(sub, logits, temperature=temperature,
                               top_k=top_k, top_p=top_p)
            toks.append(nxt)
            with tr.device_span("decode_step"):
                cache, logits = self._decode(self.params, cache, nxt[:, None],
                                             conv_filters=self._conv_filters)
            logits = logits[:, 0, :]
        return jnp.stack(toks, axis=1), {"cache_bytes": _tree_bytes(cache)}

    # ------------------------------------------------------------------
    def generate_scanned(self, key, prompt: jnp.ndarray, n_tokens: int,
                         frontend: Optional[jnp.ndarray] = None):
        """Fully-jitted greedy generation (used by benchmarks)."""
        cfg, ctx, cache_kind = self.cfg, self.ctx, self.cache_kind
        conv_filters = self._conv_filters

        @jax.jit
        def run(params, prompt):
            cache, last_logits = prefill(params, prompt, cfg,
                                         max_len=self.max_len, ctx=ctx,
                                         frontend=frontend,
                                         cache_kind=cache_kind)
            def body(carry, _):
                cache, logits = carry
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                cache, lg = decode_step(params, cache, nxt[:, None], cfg,
                                        ctx=ctx, conv_filters=conv_filters)
                return (cache, lg[:, 0, :]), nxt

            (_, _), toks = jax.lax.scan(body, (cache, last_logits), None,
                                        length=n_tokens)
            return jnp.moveaxis(toks, 0, 1)

        return run(self.params, prompt)


def _tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))
