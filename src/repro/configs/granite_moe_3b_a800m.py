"""Granite-MoE-3B-a800m [hf:ibm-granite/granite-3.0 family].

MoE: 32L d_model=1536 24H (GQA kv=8) d_ff=512 per expert, 40 experts
top-8, vocab=49155.
"""
from repro.configs.base import ATTN, MLP_MOE, MoEConfig, ModelConfig, register


@register
def granite_moe_3b_a800m() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab=49155,
        act="swiglu",
        norm="rmsnorm",
        rope_theta=10000.0,
        pattern=(ATTN,),
        mlp_kind=MLP_MOE,
        moe=MoEConfig(n_experts=40, top_k=8),
        tie_embeddings=True,
        max_seq=131072,
    )
