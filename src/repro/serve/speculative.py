"""Self-speculative decoding on the distilled recurrence (paper Sec. 3 + 5.4).

Distillation gives a *spectrum* of fidelities per filter: a low-order modal
SSM is a cheap approximation of the same pretrained convolution that the
higher-order serving SSM (or the exact Lemma-2.1 cached-conv decode) computes
faithfully. That is precisely the draft/verify pair speculative decoding
needs, with zero extra training:

  draft  — `make_draft_params` modal-truncates every Hyena layer's serving
           SSM to `draft_order` (E.3.1 influence ranking, residues refit
           against the full-order distilled filter). The draft shares every
           other weight with the target.
  verify — all K drafted tokens (plus the pending last token) run through
           ONE multi-token `decode_chunk` of the full-fidelity model, which
           returns logits at every position. Greedy slots accept the longest
           draft prefix matching the target argmax; sampled slots run
           standard rejection sampling against the *filtered* target/draft
           distributions (same `filter_logits` the per-slot sampler uses),
           so the emitted distribution equals non-speculative sampling.
  commit — rollback protocol: `snapshot_cache_slots` before the verify
           advance; after acceptance the cache is restored and the accepted
           prefix replayed with per-row `active_len` (skipped entirely via
           lax.cond when every slot accepted in full). The draft slot pool
           is advanced by the same accepted prefix from its own committed
           state (the drafting scan runs on a functional copy).

Key tree (documented in serve/README.md): every slot carries a request key
fold_in(engine_key, rid); the token at per-slot stream index t derives
fold_in(request_key, t), then a purpose tag — DRAW_TAG for direct draws from
a model distribution (non-spec ticks, draft proposals, bonus tokens),
ACCEPT_TAG for the accept/reject uniform, RESIDUAL_TAG for the residual
draw on a rejection. Spec and non-spec paths therefore consume identical
key streams per emitted-token position.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HYENA, LOCAL_ATTN, ModelConfig
from repro.core.modal import ModalSSM, eval_filter
from repro.core.truncation import modal_truncation
from repro.models.layers import NOCTX, ShardCtx
from repro.models.model import (decode_chunk, decode_step, gather_cache_rows,
                                layer_layout, restore_cache_slots,
                                snapshot_cache_slots)
from repro.serve.sampling import filter_logits, sample_token_slots

# PRNG key-tree purpose tags (see module docstring / serve/README.md)
DRAW_TAG = 1
ACCEPT_TAG = 2
RESIDUAL_TAG = 3


def token_keys(slot_keys, tok_idx, tag: int):
    """Per-(slot, stream-index) keys: fold_in(slot_key, t) then the purpose
    tag. slot_keys (B, 2) uint32; tok_idx (B,) int32. Returns (B, 2)."""
    def one(k, t):
        return jax.random.fold_in(jax.random.fold_in(k, t), tag)
    return jax.vmap(one)(slot_keys, jnp.asarray(tok_idx, jnp.int32))


def _grid_keys(slot_keys, t_grid, tag: int):
    """Keys for a (B, K) grid of stream indices. Returns (B, K, 2)."""
    def one(k, t):
        return jax.random.fold_in(jax.random.fold_in(k, t), tag)
    return jax.vmap(jax.vmap(one, in_axes=(None, 0)))(slot_keys, t_grid)


# ---------------------------------------------------------------------------
# Draft model: modal truncation of the serving SSM
# ---------------------------------------------------------------------------
def make_draft_params(params, cfg: ModelConfig, draft_order: int, *,
                      refit: bool = True, fit_len: int = 1024,
                      embed: bool = False) -> Tuple[Any, ModelConfig]:
    """Build the low-order draft: every Hyena layer's distilled modal SSM is
    truncated to `draft_order` real states (E.3.1 h-inf influence ranking);
    with refit=True the kept residues are re-solved against the FULL-ORDER
    distilled filter materialized at fit_len, so the draft tracks the
    verifier as closely as the reduced order allows. All other weights are
    shared. Non-LCSM archs (or draft_order >= distill_order) return
    (params, cfg) unchanged — self-speculation against an identical model
    still works, with ~full acceptance.

    embed=False returns compact order-draft_order params (own state shapes —
    the separate-draft-pool layout the cached-conv serving mode uses).
    embed=True exploits that modal truncation keeps a SUBSET of modes with
    their poles untouched: the truncated system's state is exactly a
    sub-vector of the serving state, so the kept (refit) residues are
    scattered back into full-order arrays with zeros on dropped modes. The
    resulting draft reads the SERVING cache directly — no second slot pool,
    no draft prefill, no draft-state advance (draft_cfg == cfg)."""
    if cfg.hyena is None or draft_order >= cfg.hyena.distill_order:
        return params, cfg
    d2 = max(draft_order // 2, 1)
    draft_cfg = cfg if embed else cfg.replace(
        hyena=dataclasses.replace(cfg.hyena, distill_order=2 * d2))

    def trunc(dp):
        ssm = ModalSSM(dp["log_a"], dp["theta"], dp["R_re"], dp["R_im"],
                       dp["h0"])
        h = eval_filter(ssm, fit_len) if refit else None
        out, idx = modal_truncation(ssm, d2, refit=refit, h=h,
                                    return_indices=True)
        if not embed:
            return {"log_a": out.log_a, "theta": out.theta, "R_re": out.R_re,
                    "R_im": out.R_im, "h0": out.h0}
        put = lambda vals: jnp.put_along_axis(
            jnp.zeros_like(dp["R_re"]), idx, vals, axis=-1, inplace=False)
        return {"log_a": dp["log_a"], "theta": dp["theta"],
                "R_re": put(out.R_re), "R_im": put(out.R_im), "h0": out.h0}

    new = jax.tree.map(lambda x: x, params)       # fresh containers
    n_groups, n_rem = layer_layout(cfg)
    for i, kind in enumerate(cfg.pattern):
        if kind == HYENA:
            new["groups"][f"l{i}"]["mix"]["distilled"] = trunc(
                params["groups"][f"l{i}"]["mix"]["distilled"])
    for i in range(n_rem):
        if cfg.blocks[n_groups * len(cfg.pattern) + i] == HYENA:
            new["rem"][i]["mix"]["distilled"] = trunc(
                params["rem"][i]["mix"]["distilled"])
    return new, draft_cfg


# ---------------------------------------------------------------------------
# Draft phase: K single-token steps fused into one executable
# ---------------------------------------------------------------------------
def draft_tokens(draft_params, draft_cache, last, K: int, cfg: ModelConfig, *,
                 temperature, top_k, top_p, slot_keys, tok_idx,
                 ctx: ShardCtx = NOCTX):
    """Draft K tokens per slot with the low-order model: a lax.scan of
    `decode_step` feeding each slot's own samples back in. Proposals for
    stream index t are drawn with the DRAW_TAG key of t — the same key the
    non-speculative path would use for that position. The advanced draft
    cache is DISCARDED: the persistent draft pool stays at the committed
    position and is advanced by the accepted prefix in the verify step.
    Returns (tokens (B, K), draft_logits (B, K, V))."""
    def body(carry, j):
        cache, tok = carry
        cache, logits = decode_step(draft_params, cache, tok[:, None], cfg,
                                    ctx=ctx)
        lg = logits[:, 0, :]
        keys = token_keys(slot_keys, tok_idx + j, DRAW_TAG)
        nxt = sample_token_slots(keys, lg, temperature=temperature,
                                 top_k=top_k, top_p=top_p)
        return (cache, nxt), (nxt, lg)

    (_, _), (toks, lgs) = jax.lax.scan(body, (draft_cache, last),
                                       jnp.arange(K, dtype=jnp.int32))
    return jnp.moveaxis(toks, 0, 1), jnp.moveaxis(lgs, 0, 1)


# ---------------------------------------------------------------------------
# Acceptance: greedy prefix match / rejection sampling
# ---------------------------------------------------------------------------
def verify_tokens(target_logits, draft_logits, tokens, spec_len, *,
                  temperature, top_k, top_p, slot_keys, tok_idx):
    """Decide per-slot acceptance and the correction token.

    target_logits: (B, C, V) from the full-fidelity multi-token verify over
    tokens (B, C) = [last, d_1..d_K]; draft_logits: (B, K, V) (q_j is the
    draft distribution d_{j+1} was proposed from); spec_len (B,) in [1, C]
    caps how many positions row b actually speculates (1 = plain decode).

    Greedy rows (temperature <= 0) accept the longest prefix where the draft
    equals the target argmax; the correction is the target argmax at the
    first mismatch (or the bonus position). Sampled rows rejection-sample:
    accept d_{j+1} with prob min(1, p_j(d)/q_j(d)) over the FILTERED
    distributions, emit a residual draw from norm(max(p - q, 0)) on the
    first rejection, or a direct target draw for the bonus / non-spec rows.

    Returns (emitted (B, C) int32 — first n_emit entries valid per row,
    n_emit (B,) in [1, spec_len], n_acc (B,), correction (B,)).

    An all-greedy fast path (lax.cond) skips the filtered-distribution and
    rejection machinery entirely — the serving hot loop is usually greedy."""
    B, C, V = target_logits.shape
    K = C - 1
    assert K >= 1, "verify needs at least one drafted token"
    tok_idx = jnp.asarray(tok_idx, jnp.int32)
    spec_len = jnp.asarray(spec_len, jnp.int32)
    temperature = jnp.asarray(temperature, jnp.float32)
    greedy_row = temperature <= 0.0
    g = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)        # (B, C)
    drafts = tokens[:, 1:]                                          # (B, K)
    match_g = drafts == g[:, :K]

    def run_len(match):
        return jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)

    def greedy_branch(_):
        n_acc = jnp.minimum(run_len(match_g), spec_len - 1)
        g_r = jnp.take_along_axis(g, n_acc[:, None], axis=1)[:, 0]
        return n_acc, g_r

    def sampled_branch(_):
        flat = lambda x: x.reshape(B * K, V)
        rep = lambda p: jnp.repeat(p, K, axis=0)
        p_prob = jax.nn.softmax(filter_logits(
            flat(target_logits[:, :K]), temperature=rep(temperature),
            top_k=rep(top_k), top_p=rep(top_p)).reshape(B, K, V), axis=-1)
        q_prob = jax.nn.softmax(filter_logits(
            flat(draft_logits), temperature=rep(temperature),
            top_k=rep(top_k), top_p=rep(top_p)).reshape(B, K, V), axis=-1)
        p_d = jnp.take_along_axis(p_prob, drafts[..., None], -1)[..., 0]
        q_d = jnp.take_along_axis(q_prob, drafts[..., None], -1)[..., 0]
        t_grid = tok_idx[:, None] + jnp.arange(K, dtype=jnp.int32)[None, :]
        u = jax.vmap(jax.vmap(jax.random.uniform))(
            _grid_keys(slot_keys, t_grid, ACCEPT_TAG))
        accept_s = u * jnp.clip(q_d, 1e-30) <= p_d
        match = jnp.where(greedy_row[:, None], match_g, accept_s)
        n_acc = jnp.minimum(run_len(match), spec_len - 1)
        r = n_acc
        # correction token at position r (per row)
        corr_keys = token_keys(slot_keys, tok_idx + r, DRAW_TAG)
        res_keys = token_keys(slot_keys, tok_idx + r, RESIDUAL_TAG)
        p_r = filter_logits(
            jnp.take_along_axis(target_logits, r[:, None, None],
                                axis=1)[:, 0],
            temperature=temperature, top_k=top_k, top_p=top_p)      # (B, V)
        direct = jax.vmap(jax.random.categorical)(corr_keys,
                                                  p_r).astype(jnp.int32)
        # genuine rejection (not the spec_len cap, not the bonus slot)
        rejected = r < jnp.minimum(spec_len - 1, K)
        p_at_r = jnp.take_along_axis(
            p_prob, jnp.minimum(r, K - 1)[:, None, None], axis=1)[:, 0]
        q_at_r = jnp.take_along_axis(
            q_prob, jnp.minimum(r, K - 1)[:, None, None], axis=1)[:, 0]
        diff = jnp.maximum(p_at_r - q_at_r, 0.0)
        ok = jnp.sum(diff, axis=-1, keepdims=True) > 1e-12
        res_lg = jnp.where(ok & (diff > 0.0), jnp.log(jnp.clip(diff, 1e-30)),
                           -jnp.inf)
        # degenerate residual (p == q exactly): fall back to a direct draw
        res_lg = jnp.where(ok, res_lg, jnp.log(jnp.clip(p_at_r, 1e-30)))
        residual = jax.vmap(jax.random.categorical)(
            res_keys, res_lg).astype(jnp.int32)
        corr_sampled = jnp.where(rejected, residual, direct)
        g_r = jnp.take_along_axis(g, r[:, None], axis=1)[:, 0]
        return n_acc, jnp.where(greedy_row, g_r, corr_sampled)

    n_acc, correction = jax.lax.cond(jnp.all(greedy_row), greedy_branch,
                                     sampled_branch, None)

    jgrid = jnp.arange(C, dtype=jnp.int32)[None, :]
    drafts_pad = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((B, 1), jnp.int32)], axis=1)      # (B, C)
    emitted = jnp.where(jgrid < n_acc[:, None], drafts_pad,
                        jnp.where(jgrid == n_acc[:, None],
                                  correction[:, None], 0))
    return emitted, n_acc + 1, n_acc, correction


# ---------------------------------------------------------------------------
# Verify + commit: one fused executable per tick
# ---------------------------------------------------------------------------
def spec_verify_commit(params, draft_params, cache, last, draft_toks,
                       draft_logits, spec_len, draft_cache, cfg: ModelConfig,
                       draft_cfg: ModelConfig, *, temperature, top_k, top_p,
                       slot_keys, tok_idx, ctx: ShardCtx = NOCTX,
                       conv_filters=None, select_commit: bool = False):
    """One speculative round against the slot pools (see module docstring).

    Rollback protocol, two implementations:
      * select_commit=True (pure distilled-Hyena archs): the verify
        decode_chunk collects per-position states and the committed cache is
        SELECTED at each row's accepted length (`commit_cache_from_states`)
        — one forward pass total.
      * generic: snapshot -> decode_chunk over C = K+1 tokens with per-row
        active_len = spec_len (logits at every position) -> acceptance ->
        restore + replay with active_len = n_emit (logits skipped). The
        replay is skipped entirely via lax.cond when every slot accepted in
        full (the verify advance already IS the committed state then).

    `draft_cache` is None for the state-sharing draft (embed=True draft
    params read the serving cache — nothing to advance); for the
    separate-pool draft (cached-conv mode) it is still at the committed
    position — the drafting scan ran on a copy — and is advanced here by
    the same accepted prefix.

    Returns (cache, draft_cache_or_None, emitted (B, C), n_emit (B,),
    new_last (B,), new_tok_idx (B,))."""
    B, K = draft_toks.shape
    tokens = jnp.concatenate([last[:, None], draft_toks], axis=1)   # (B, C)
    if select_commit:
        from repro.models.model import commit_cache_from_states
        _, logits, aux = decode_chunk(params, cache, tokens, cfg,
                                      active_len=spec_len, ctx=ctx,
                                      conv_filters=conv_filters,
                                      collect_states=True)
        emitted, n_emit, n_acc, correction = verify_tokens(
            logits, draft_logits, tokens, spec_len, temperature=temperature,
            top_k=top_k, top_p=top_p, slot_keys=slot_keys, tok_idx=tok_idx)
        new_cache = commit_cache_from_states(aux, n_emit, cfg)
    else:
        snap = snapshot_cache_slots(cache, cfg, K + 1)
        cache1, logits = decode_chunk(params, cache, tokens, cfg,
                                      active_len=spec_len, ctx=ctx,
                                      conv_filters=conv_filters)
        emitted, n_emit, n_acc, correction = verify_tokens(
            logits, draft_logits, tokens, spec_len, temperature=temperature,
            top_k=top_k, top_p=top_p, slot_keys=slot_keys, tok_idx=tok_idx)

        def keep(args):
            cache1, _ = args
            return cache1

        def roll(args):
            cache1, snap = args
            rb = restore_cache_slots(cache1, snap, cfg)
            c2, _ = decode_chunk(params, rb, tokens, cfg, active_len=n_emit,
                                 ctx=ctx, conv_filters=conv_filters,
                                 need_logits=False)
            return c2

        new_cache = jax.lax.cond(jnp.all(n_emit == spec_len), keep, roll,
                                 (cache1, snap))
    new_draft_cache = None
    if draft_cache is not None:
        new_draft_cache, _ = decode_chunk(draft_params, draft_cache, tokens,
                                          draft_cfg, active_len=n_emit,
                                          ctx=ctx, need_logits=False)
    return (new_cache, new_draft_cache, emitted, n_emit, correction,
            tok_idx + n_emit)


def spec_round(params, draft_params, cache, last, spec_len, draft_cache,
               K: int, cfg: ModelConfig, draft_cfg: ModelConfig, *,
               temperature, top_k, top_p, slot_keys, tok_idx,
               ctx: ShardCtx = NOCTX, conv_filters=None,
               select_commit: bool = False):
    """One full speculative round — draft scan + verify/commit — fused into
    a single executable so the serving loop pays ONE dispatch per up to
    K + 1 tokens per slot. The draft scan reads the serving cache itself
    when draft_cache is None (state-sharing draft), else the separate draft
    pool; either way its advanced state is discarded and only the accepted
    prefix is committed."""
    draft_src = cache if draft_cache is None else draft_cache
    draft_toks, draft_logits = draft_tokens(
        draft_params, draft_src, last, K, draft_cfg, temperature=temperature,
        top_k=top_k, top_p=top_p, slot_keys=slot_keys, tok_idx=tok_idx,
        ctx=ctx)
    return spec_verify_commit(
        params, draft_params, cache, last, draft_toks, draft_logits,
        spec_len, draft_cache, cfg, draft_cfg, temperature=temperature,
        top_k=top_k, top_p=top_p, slot_keys=slot_keys, tok_idx=tok_idx,
        ctx=ctx, conv_filters=conv_filters, select_commit=select_commit)


# ---------------------------------------------------------------------------
# Top-k tree drafts: `branch` root-to-leaf chains verified in ONE decode_chunk
# ---------------------------------------------------------------------------
def draft_tree(draft_params, draft_cache, last, K: int, branch: int,
               cfg: ModelConfig, *, temperature, top_k, top_p, slot_keys,
               tok_idx, ctx: ShardCtx = NOCTX):
    """Draft a depth-K, branching-factor-`branch` token tree per slot,
    flattened into `branch` root-to-leaf chains laid out slot-major over an
    expanded batch of B * branch rows (row = slot * branch + c).

    The tree branches ONCE, at depth 0: chain 0 draws with the slot's own
    sampler and DRAW_TAG key stream — byte-identical to the single-chain
    draft, which is what keeps greedy output token-identical and lets
    sampled rows run standard rejection sampling against chain 0 — while
    chains c >= 1 take the c-th-ranked (top-k) first token and continue
    greedily. The branch point is where a draft most often diverges from the
    target; covering the runners-up there lifts acceptance at the same
    single verify call (over the replicated rows).

    Returns (draft_toks (B*branch, K), draft_logits (B*branch, K, V)). The
    advanced draft state is discarded, as in `draft_tokens`."""
    B = last.shape[0]
    b = branch
    cache1, logits = decode_step(draft_params, draft_cache, last[:, None],
                                 cfg, ctx=ctx)
    lg0 = logits[:, 0, :]
    keys0 = token_keys(slot_keys, tok_idx, DRAW_TAG)
    chain0 = sample_token_slots(keys0, lg0, temperature=temperature,
                                top_k=top_k, top_p=top_p)
    _, ranked = jax.lax.top_k(lg0, b)                            # (B, b)
    toks0 = jnp.concatenate([chain0[:, None], ranked[:, 1:].astype(jnp.int32)],
                            axis=1)                              # (B, b)
    rows = jnp.repeat(jnp.arange(B, dtype=jnp.int32), b)
    cache_e = gather_cache_rows(cache1, rows)
    last_e = toks0.reshape(B * b)
    lg0_e = jnp.repeat(lg0, b, axis=0)       # depth-0 proposal distribution
    # chain 0 keeps the slot's sampling params + key stream; side chains
    # continue greedily (their depth-0 token already diversified the tree)
    is_c0 = (jnp.arange(B * b, dtype=jnp.int32) % b) == 0
    temp_e = jnp.where(is_c0, jnp.repeat(temperature, b), 0.0)
    topk_e = jnp.where(is_c0, jnp.repeat(top_k, b), 0)
    topp_e = jnp.where(is_c0, jnp.repeat(top_p, b), 1.0)
    keys_e = jnp.repeat(slot_keys, b, axis=0)
    ti_e = jnp.repeat(jnp.asarray(tok_idx, jnp.int32), b)
    if K == 1:
        return last_e[:, None], lg0_e[:, None]

    def body(carry, j):
        cache, tok = carry
        cache, lg = decode_step(draft_params, cache, tok[:, None], cfg,
                                ctx=ctx)
        lg = lg[:, 0, :]
        keys = token_keys(keys_e, ti_e + j, DRAW_TAG)
        nxt = sample_token_slots(keys, lg, temperature=temp_e, top_k=topk_e,
                                 top_p=topp_e)
        return (cache, nxt), (nxt, lg)

    (_, _), (toks, lgs) = jax.lax.scan(body, (cache_e, last_e),
                                       jnp.arange(1, K, dtype=jnp.int32))
    draft_toks = jnp.concatenate([last_e[:, None], jnp.moveaxis(toks, 0, 1)],
                                 axis=1)
    draft_lgs = jnp.concatenate([lg0_e[:, None], jnp.moveaxis(lgs, 0, 1)],
                                axis=1)
    return draft_toks, draft_lgs


def spec_round_tree(params, draft_params, cache, last, spec_len, draft_cache,
                    K: int, branch: int, cfg: ModelConfig,
                    draft_cfg: ModelConfig, *, temperature, top_k, top_p,
                    slot_keys, tok_idx, ctx: ShardCtx = NOCTX,
                    conv_filters=None, select_commit: bool = False):
    """One speculative round over a top-k token tree. All `branch` chains of
    every slot are verified in ONE decode_chunk over a replicated scratch
    pool (`gather_cache_rows` — the real pool is never advanced by a
    rejected chain), the winning chain per slot is the one with the longest
    window-capped greedy run (ties -> chain 0; sampled rows always take
    chain 0, whose proposals came from the slot's own rejection-samplable
    stream), and only the winner is committed: selection-commit gathers the
    winner's per-position states from the verify aux, the generic path
    replays the winner's accepted prefix on the real pool. branch=1 reduces
    to the chain round (same acceptance, one extra gather).

    Same signature/returns as `spec_round` plus `branch`."""
    B = last.shape[0]
    b = branch
    draft_src = cache if draft_cache is None else draft_cache
    draft_toks_e, draft_lgs_e = draft_tree(
        draft_params, draft_src, last, K, b, draft_cfg,
        temperature=temperature, top_k=top_k, top_p=top_p,
        slot_keys=slot_keys, tok_idx=tok_idx, ctx=ctx)
    rows = jnp.repeat(jnp.arange(B, dtype=jnp.int32), b)
    tokens_e = jnp.concatenate([jnp.take(last, rows)[:, None], draft_toks_e],
                               axis=1)                           # (B*b, C)
    spec_len = jnp.asarray(spec_len, jnp.int32)
    spec_len_e = jnp.take(spec_len, rows)
    cache_e = gather_cache_rows(cache, rows)
    if select_commit:
        from repro.models.model import commit_cache_from_states
        _, logits_e, aux_e = decode_chunk(params, cache_e, tokens_e, cfg,
                                          active_len=spec_len_e, ctx=ctx,
                                          conv_filters=conv_filters,
                                          collect_states=True)
    else:
        _, logits_e = decode_chunk(params, cache_e, tokens_e, cfg,
                                   active_len=spec_len_e, ctx=ctx,
                                   conv_filters=conv_filters)
    # winner = longest window-capped greedy run per slot (ties -> chain 0);
    # sampled rows are pinned to chain 0 for distribution exactness
    g_e = jnp.argmax(logits_e[:, :K, :], axis=-1).astype(jnp.int32)
    run = jnp.sum(jnp.cumprod((draft_toks_e == g_e).astype(jnp.int32),
                              axis=1), axis=1)
    n_acc_e = jnp.minimum(run, spec_len_e - 1).reshape(B, b)
    greedy_row = jnp.asarray(temperature, jnp.float32) <= 0.0
    winner = jnp.where(greedy_row,
                       jnp.argmax(n_acc_e, axis=1).astype(jnp.int32), 0)
    widx = jnp.arange(B, dtype=jnp.int32) * b + winner
    emitted, n_emit, n_acc, correction = verify_tokens(
        jnp.take(logits_e, widx, axis=0), jnp.take(draft_lgs_e, widx, axis=0),
        jnp.take(tokens_e, widx, axis=0), spec_len, temperature=temperature,
        top_k=top_k, top_p=top_p, slot_keys=slot_keys, tok_idx=tok_idx)
    tokens_w = jnp.take(tokens_e, widx, axis=0)
    if select_commit:
        new_cache = commit_cache_from_states(
            gather_cache_rows(aux_e, widx), n_emit, cfg)
    else:
        # the verify ran on a scratch copy, so committing IS the replay —
        # advance the untouched real pool by the winner's accepted prefix
        new_cache, _ = decode_chunk(params, cache, tokens_w, cfg,
                                    active_len=n_emit, ctx=ctx,
                                    conv_filters=conv_filters,
                                    need_logits=False)
    new_draft_cache = None
    if draft_cache is not None:
        new_draft_cache, _ = decode_chunk(draft_params, draft_cache, tokens_w,
                                          draft_cfg, active_len=n_emit,
                                          ctx=ctx, need_logits=False)
    return (new_cache, new_draft_cache, emitted, n_emit, correction,
            jnp.asarray(tok_idx, jnp.int32) + n_emit)


# ---------------------------------------------------------------------------
# Jitted entry points (shared memo with the other serving executables)
# ---------------------------------------------------------------------------
def jitted_spec_round(cfg: ModelConfig, draft_cfg: ModelConfig, K: int,
                      shared_draft: bool, ctx: ShardCtx = NOCTX,
                      branch: int = 1, *, out_shardings=None, shard_key=None):
    """Positional args: (params, draft_params, cache, last, spec_len,
    draft_cache) — pass draft_cache=None with shared_draft=True. The
    serving cache (and the draft pool, when separate) is donated. The
    selection-commit is enabled automatically for archs that support it.
    branch >= 2 compiles the top-k tree round (`spec_round_tree`).
    `out_shardings` pins the round's output layout for a sharded slot pool
    (see `jitted_decode_step`); `shard_key` keeps the sharded executable
    distinct in the shared memo."""
    from repro.models.model import supports_state_select
    from repro.serve.engine import _JIT_CACHE
    sel = shared_draft and supports_state_select(cfg)
    key = ("spec_round", cfg, draft_cfg, K, shared_draft, branch, id(ctx),
           shard_key)
    if key not in _JIT_CACHE:
        fn = (spec_round if branch <= 1
              else functools.partial(spec_round_tree, branch=branch))
        kw = {} if out_shardings is None else {"out_shardings": out_shardings}
        _JIT_CACHE[key] = jax.jit(
            functools.partial(fn, K=K, cfg=cfg, draft_cfg=draft_cfg,
                              ctx=ctx, select_commit=sel),
            donate_argnums=(2,) if shared_draft else (2, 5), **kw)
    return _JIT_CACHE[key]


def spec_round_levels(spec_k: int) -> List[int]:
    """Compiled speculation depths: powers of two up to spec_k, plus spec_k.
    The scheduler picks the smallest level covering the round's widest live
    window, so a shrunk window actually saves draft/verify compute instead
    of masking it."""
    out = []
    level = 1
    while level < spec_k:
        out.append(level)
        level *= 2
    out.append(spec_k)
    return out


def validate_spec_config(cfg: ModelConfig, spec_k: int,
                         branch: int = 1) -> None:
    """Speculation horizon constraints: ring buffers must hold a whole
    verify window (snapshot regions would alias otherwise)."""
    if spec_k < 1:
        raise ValueError(f"spec_k must be >= 1, got {spec_k}")
    if branch < 1:
        raise ValueError(f"spec branch must be >= 1, got {branch}")
    if any(b == LOCAL_ATTN for b in cfg.blocks) and cfg.window > 0 \
            and cfg.window < spec_k + 1:
        raise ValueError(
            f"spec_k={spec_k} needs window >= {spec_k + 1} for the ring "
            f"snapshot (got window={cfg.window})")
    if cfg.enc_dec or cfg.frontend != "none":
        raise ValueError("speculative decoding does not support "
                         "enc-dec/frontend architectures")


# ---------------------------------------------------------------------------
# Acceptance-driven control: per-slot online windows + per-engine autotuning
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SpecControllerConfig:
    """Knobs of the per-slot speculation-window control law (see
    SlotSpecController)."""
    ema: float = 0.6            # weight on the PAST in the acceptance EMA
    min_rounds: int = 3         # rounds at full depth before adapting
    marginal: float = 0.25      # keep draft depth j while a^j >= marginal
    disable_below: float = 0.08 # EMA acceptance below this -> spec off
    probe_every: int = 32       # rounds between re-probes of an off slot


class SlotSpecController:
    """Per-slot speculation windows from each request's running acceptance.

    Every speculative round feeds back (drafted, accepted) per slot;
    the controller keeps an EMA `a` of the per-round acceptance fraction
    (initialized optimistically at 1.0) and sets the slot's verify window:

        a <  disable_below  ->  window 1 (speculation off for the slot)
        otherwise           ->  1 + clip(floor(log marginal / log a), 1, K)

    i.e. draft only to the depth where the expected chance a^j that the
    whole prefix survives still clears `marginal` — a geometric-yield
    cutoff, which is the right shape because a chain draft's value decays
    geometrically in its depth. A disabled slot is re-probed with a
    depth-1 round every `probe_every` ticks, so a request whose tail turns
    predictable gets speculation back.

    Correctness does not depend on any of this: the verify/commit path is
    exact for EVERY per-slot window sequence (greedy output stays
    token-identical to plain decoding; sampled output keeps its
    distribution), so the controller is free to chase throughput only.
    Host-side and O(n_slots) per round.

    With a serve.metrics.MetricsRegistry bound (`metrics=`), the control
    law reports itself: per-round acceptance fractions land in the
    `serve_spec_acceptance` histogram, and the
    `serve_spec_ctl_disables` / `serve_spec_ctl_probes` counters track
    slots turned off by low acceptance and idle slots re-probed."""

    def __init__(self, n_slots: int, spec_k: int,
                 cfg: Optional[SpecControllerConfig] = None, *,
                 metrics=None):
        self.k = int(spec_k)
        self.cfg = cfg or SpecControllerConfig()
        self._a = np.ones(n_slots, np.float64)
        self._rounds = np.zeros(n_slots, np.int64)
        self._idle = np.zeros(n_slots, np.int64)
        self._win = np.ones(n_slots, np.int32)
        self._enabled = np.zeros(n_slots, bool)
        if metrics is None:            # null instruments: bumps are no-ops
            from repro.serve.metrics import MetricsRegistry
            metrics = MetricsRegistry(enabled=False)
        from repro.serve.metrics import RATIO_BUCKETS
        self._h_accept = metrics.histogram(
            "serve_spec_acceptance", RATIO_BUCKETS,
            help="per-round accepted/drafted fraction fed to the controller")
        self._c_disable = metrics.counter(
            "serve_spec_ctl_disables",
            help="slots whose EMA acceptance fell below disable_below")
        self._c_probe = metrics.counter(
            "serve_spec_ctl_probes",
            help="depth-1 probe rounds granted to idle (disabled) slots")

    def admit(self, slot: int, enabled: bool) -> int:
        self._a[slot] = 1.0
        self._rounds[slot] = 0
        self._idle[slot] = 0
        self._enabled[slot] = bool(enabled)
        self._win[slot] = self.k + 1 if enabled else 1
        return int(self._win[slot])

    def evict(self, slot: int) -> None:
        self._enabled[slot] = False
        self._win[slot] = 1

    def window(self, slot: int) -> int:
        return int(self._win[slot])

    def on_round(self, slot: int) -> int:
        """Window to use for the round being dispatched. Off slots count
        idle rounds and widen to a one-round depth-1 probe when due."""
        if not self._enabled[slot]:
            return 1
        if self._win[slot] == 1:
            self._idle[slot] += 1
            if self._idle[slot] >= self.cfg.probe_every:
                self._idle[slot] = 0
                self._c_probe.inc()
                return 2
        return int(self._win[slot])

    def observe(self, slot: int, drafted: int, accepted: int) -> int:
        """Feed back one round's (drafted, accepted) for the slot; returns
        the slot's new window."""
        if not self._enabled[slot] or drafted <= 0:
            return int(self._win[slot])
        c = self.cfg
        frac = min(max(accepted / drafted, 0.0), 1.0)
        self._h_accept.observe(frac)
        self._a[slot] = c.ema * self._a[slot] + (1.0 - c.ema) * frac
        self._rounds[slot] += 1
        if self._rounds[slot] < c.min_rounds:
            return int(self._win[slot])
        a = float(self._a[slot])
        if a < c.disable_below:
            w = 1
            if self._win[slot] > 1:
                self._c_disable.inc()
        elif a >= 0.999:
            w = self.k + 1
        else:
            depth = int(math.floor(math.log(c.marginal) / math.log(a)))
            w = 1 + max(1, min(self.k, depth))
        self._win[slot] = w
        return w


@dataclasses.dataclass(frozen=True)
class SpecCandidate:
    """One (spec_k, draft_order, branch) configuration the autotuner
    measures. draft_order=None means the engine default (half the serving
    distill order); draft_order >= distill_order is the full-order draft —
    speculation degenerates into fused multi-token decode (acceptance 1),
    which still wins when per-tick dispatch/sampler overhead dominates."""
    spec_k: int
    draft_order: Optional[int] = None
    branch: int = 1

    def label(self) -> str:
        d = "half" if self.draft_order is None else str(self.draft_order)
        out = f"k{self.spec_k}/d{d}"
        if self.branch > 1:
            out += f"/b{self.branch}"
        return out


@dataclasses.dataclass
class AutotuneReport:
    """Result of `autotune_spec`: the measured table and the chosen
    candidate (None -> speculation off beats every candidate)."""
    chosen: Optional[SpecCandidate]
    plain: Dict[str, Any]
    candidates: List[Tuple[SpecCandidate, Dict[str, Any]]]
    margin: float

    def table(self) -> List[Dict[str, Any]]:
        rows = [{"config": "plain", **self.plain}]
        for c, m in self.candidates:
            rows.append({"config": c.label(), "spec_k": c.spec_k,
                         "draft_order": c.draft_order, "branch": c.branch,
                         **m})
        return rows

    def pretty(self) -> str:
        lines = [f"{'config':>12s} {'decode tok/s':>12s} {'accept':>7s} "
                 f"{'tok/round':>9s}"]
        for r in self.table():
            acc = r.get("acceptance")
            tpr = r.get("tokens_per_slot_round")
            lines.append(
                f"{r['config']:>12s} {r.get('decode_tok_per_s', 0.0):12.1f} "
                f"{acc if acc is not None else float('nan'):7.2f} "
                f"{tpr if tpr is not None else float('nan'):9.2f}"
                + ("   <- chosen" if self.chosen is not None
                   and r["config"] == self.chosen.label() else ""))
        if self.chosen is None:
            lines.append(f"(no candidate beat plain decode by "
                         f">{self.margin:.0%}: speculation disabled)")
        return "\n".join(lines)


def default_spec_candidates(cfg: ModelConfig) -> List[SpecCandidate]:
    """Default autotune sweep. For LCSM archs: half- and full-order chain
    drafts at two depths plus one top-k tree config; the full-order draft is
    in the pool on purpose — with the state-sharing draft it is a pure
    fused-multi-token-decode play and often the CPU winner. Non-LCSM archs
    have no truncation axis, so only the depth varies."""
    if cfg.hyena is not None:
        full = cfg.hyena.distill_order
        half = max(full // 2, 1)
        return [SpecCandidate(4, full), SpecCandidate(4, half),
                SpecCandidate(2, full), SpecCandidate(2, half, branch=2)]
    return [SpecCandidate(4), SpecCandidate(2)]


def autotune_spec(params, cfg: ModelConfig, *, mode: str = "distilled",
                  n_slots: int = 4, max_len: int = 256,
                  candidates: Optional[Sequence[SpecCandidate]] = None,
                  margin: float = 0.05, seed: int = 0, ctx: ShardCtx = NOCTX,
                  prompt_len: Optional[int] = None,
                  target_tokens: Optional[int] = None,
                  draft_model: Optional[Tuple[Any, ModelConfig]] = None,
                  engine_kwargs: Optional[Dict[str, Any]] = None
                  ) -> AutotuneReport:
    """Measure plain decode and every candidate speculative config under a
    saturated-slot workload (`measure_saturated_decode` — every slot busy,
    pure decode ticks, so the number is not diluted by arrival gaps) and
    pick the fastest. A candidate is chosen only if it beats plain decode
    by more than `margin`; otherwise the report's `chosen` is None and the
    engine should serve without speculation. Candidate engines share the
    process-wide jit memo, so the sweep compiles each distinct
    (K, branch) executable once, not once per candidate."""
    from repro.serve.scheduler import (ContinuousBatchingEngine,
                                       measure_saturated_decode)
    if candidates is None:
        candidates = default_spec_candidates(cfg)
    if prompt_len is None:
        prompt_len = max(8, min(32, max_len // 4))

    def run(spec_k: int, draft_order=None, branch: int = 1) -> Dict[str, Any]:
        eng = ContinuousBatchingEngine(
            params, cfg, n_slots=n_slots, max_len=max_len, mode=mode,
            ctx=ctx, seed=seed, spec_k=spec_k, draft_order=draft_order,
            spec_branch=branch, spec_adapt=False, draft_model=draft_model,
            **(engine_kwargs or {}))
        eng.warmup((prompt_len,))
        return measure_saturated_decode(eng, prompt_len=prompt_len,
                                        target_tokens=target_tokens)

    plain = run(0)
    measured: List[Tuple[SpecCandidate, Dict[str, Any]]] = []
    for c in candidates:
        try:
            m = run(c.spec_k, c.draft_order, c.branch)
        except ValueError as e:        # e.g. ring window < verify horizon
            m = {"decode_tok_per_s": 0.0, "error": str(e)}
        measured.append((c, m))
    chosen = None
    if measured:
        best, best_m = max(measured,
                           key=lambda cm: cm[1].get("decode_tok_per_s", 0.0))
        if best_m.get("decode_tok_per_s", 0.0) \
                >= (1.0 + margin) * plain["decode_tok_per_s"]:
            chosen = best
    return AutotuneReport(chosen=chosen, plain=plain, candidates=measured,
                          margin=margin)
