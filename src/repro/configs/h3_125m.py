"""H3-125M (hybrid) — the paper's other LCSM family [Fu et al., 2023].

12L d_model=768 12H d_ff=3072 vocab=50264; H3 blocks parameterize the long
filter as a diagonal SSM (64 modes) with a width-4 shift conv; following the
paper's benchmark setup ("hybrid H3-attention model with 2 attention
layers"), attention sits at layers 1 and 7 (period-6 pattern).

Distilling H3 is model-order reduction (paper Sec. 3: "the term distillation
becomes analogous to model-order reduction"); App. E.3 compares modal and
balanced truncation on exactly this family.
"""
from repro.configs.base import ATTN, HYENA, HyenaConfig, ModelConfig, register


@register
def h3_125m() -> ModelConfig:
    return ModelConfig(
        name="h3-125m",
        family="lcsm",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab=50264,
        act="gelu",
        norm="layernorm",
        pattern=(HYENA, ATTN, HYENA, HYENA, HYENA, HYENA),
        hyena=HyenaConfig(n_filter_heads=12, filter_param="ssm", ssm_state=64,
                          short_conv=4, distill_order=8),
        tie_embeddings=True,
        max_seq=1_048_576,
    )
