"""Observability layer: metrics registry math and exposition, the span
tracer and its Chrome-trace export, and the scheduler integration — an
exported request trace must reconstruct the measured TTFT / end-to-end
latency exactly, recovery events must land on the affected request's
timeline, and telemetry-on serving must stay at zero steady-state
compiles."""
import json
import math
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HYENA, HyenaConfig, ModelConfig
from repro.distributed.sharding import unzip
from repro.models.model import init_params
from repro.serve.faults import FaultInjector
from repro.serve.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                                 RESILIENCE_KEYS, ResilienceCounters,
                                 count_compiles, jit_cache_size,
                                 speculative_summary, start_metrics_server)
from repro.serve.scheduler import ContinuousBatchingEngine
from repro.serve.trace import (HOST_PID, NULL_TRACER, REQUEST_PID, Tracer)

MAX_LEN = 48
PROMPT_LENS = (4, 7, 12, 20, 9)
GEN_LENS = (8, 5, 11, 6, 9)


def _hyena_cfg(name="obs-hyena"):
    return ModelConfig(name=name, family="lcsm", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, vocab=64, act="gelu", norm="layernorm",
                       pattern=(HYENA,),
                       hyena=HyenaConfig(n_filter_heads=2, filter_order=16,
                                         filter_emb=9, distill_order=8),
                       max_seq=512, dtype="float32")


@pytest.fixture(scope="module")
def hyena_model():
    cfg = _hyena_cfg()
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _prompts(vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32)
            for n in PROMPT_LENS]


# ---------------------------------------------------------------------------
# histogram / percentile math
# ---------------------------------------------------------------------------
def test_histogram_buckets_and_counts():
    h = Histogram("h", buckets=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.5, 3.0, 10.0):
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(16.0)
    snap = h.snapshot()
    # cumulative: <=1 holds {0.5, 1.0}, <=2 adds 1.5, <=5 adds 3.0, +Inf all
    assert snap["buckets"] == {"1": 2, "2": 3, "5": 4, "+Inf": 5}
    assert snap["min"] == 0.5 and snap["max"] == 10.0


def test_histogram_percentile_properties():
    h = Histogram("h", buckets=(0.001, 0.01, 0.1, 1.0))
    rng = np.random.default_rng(0)
    vals = rng.uniform(0.0005, 0.5, size=500)
    for v in vals:
        h.observe(float(v))
    qs = [0, 10, 25, 50, 75, 90, 99, 100]
    est = [h.percentile(q) for q in qs]
    # monotone in q, clamped to the observed range
    assert all(a <= b + 1e-12 for a, b in zip(est, est[1:]))
    assert est[0] >= vals.min() and est[-1] <= vals.max()
    # bucketed estimate lands near the true quantile (bucket-width bound)
    true_p50 = float(np.percentile(vals, 50))
    assert abs(est[3] - true_p50) < 0.1


def test_histogram_empty_and_single():
    h = Histogram("h", buckets=(1.0,))
    assert math.isnan(h.percentile(50))
    assert h.snapshot()["p50"] is None
    h.observe(0.25)
    # one observation: every percentile is that value (min==max clamp)
    assert h.percentile(1) == pytest.approx(0.25)
    assert h.percentile(99) == pytest.approx(0.25)


def test_histogram_rejects_bad_buckets():
    with pytest.raises(ValueError):
        Histogram("h", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", buckets=(1.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", buckets=())


# ---------------------------------------------------------------------------
# registry: get-or-create, kind safety, disabled mode, exposition
# ---------------------------------------------------------------------------
def test_registry_get_or_create_and_kind_clash():
    reg = MetricsRegistry()
    c = reg.counter("serve_x", help="things")
    assert reg.counter("serve_x") is c          # same instrument back
    c.inc()
    c.inc(2)
    assert c.value == 3
    g = reg.gauge("serve_depth")
    g.set(7)
    g.inc(-2)
    assert g.value == 5
    with pytest.raises(ValueError):
        reg.gauge("serve_x")                    # kind clash
    assert reg.get("serve_x") is c
    assert reg.get("nope") is None              # get() never creates
    assert "nope" not in reg.names()


def test_registry_disabled_is_nullop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("serve_x")
    h = reg.histogram("serve_h")
    assert c is reg.gauge("anything")           # one shared null instrument
    c.inc()
    h.observe(1.0)
    assert h.count == 0 and math.isnan(h.percentile(50))
    assert reg.names() == []
    assert reg.snapshot() == {}
    assert reg.to_prometheus().strip() == ""


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("serve_reqs", help="finished requests").inc(3)
    reg.gauge("serve_depth").set(2)
    h = reg.histogram("serve_lat", buckets=(0.1, 1.0), help="latency")
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.to_prometheus()
    assert "# HELP serve_reqs finished requests" in text
    assert "# TYPE serve_reqs counter" in text
    assert "serve_reqs 3" in text
    assert "# TYPE serve_depth gauge" in text
    assert "serve_depth 2" in text
    assert "# TYPE serve_lat histogram" in text
    assert 'serve_lat_bucket{le="0.1"} 1' in text
    assert 'serve_lat_bucket{le="1"} 2' in text
    assert 'serve_lat_bucket{le="+Inf"} 3' in text
    assert "serve_lat_sum 5.55" in text
    assert "serve_lat_count 3" in text
    assert text.endswith("\n")


def test_resilience_counters_feed_registry():
    reg = MetricsRegistry()
    res = ResilienceCounters(registry=reg)
    res.bump("health_failures")
    res.bump("health_failures", 2)
    assert res.get("health_failures") == 3
    assert reg.get("serve_resilience_health_failures").value == 3
    res.reset()                                 # snapshot resets ...
    assert res.get("health_failures") == 0
    assert sorted(res.snapshot()) == sorted(RESILIENCE_KEYS)
    # ... but the registry counter stays monotonic (Prometheus semantics)
    assert reg.get("serve_resilience_health_failures").value == 3


# ---------------------------------------------------------------------------
# jit_cache_size: cross-version probing, loud degradation
# ---------------------------------------------------------------------------
def test_jit_cache_size_probes_known_spellings():
    class Method:
        def _cache_size(self):
            return 4

    class Attr:
        cache_size = 7

    class NewSpelling:                          # method under the new name
        def cache_size(self):
            return 2

    assert jit_cache_size(Method()) == 4
    assert jit_cache_size(Attr()) == 7
    assert jit_cache_size(NewSpelling()) == 2


def test_jit_cache_size_on_real_jitted_fn(hyena_model):
    """The probe must resolve on this jax version for at least a freshly
    jitted callable — if it returns None here, compile accounting silently
    degraded and the probe list needs a new spelling."""
    fn = jax.jit(lambda x: x + 1)
    fn(jnp.zeros((2,)))
    n = jit_cache_size(fn)
    assert n is not None and n >= 1


def test_jit_cache_size_degrades_loudly(monkeypatch):
    import repro.serve.metrics as M

    class Opaque:
        pass

    monkeypatch.setattr(M, "_jit_cache_warned", False)
    with pytest.warns(RuntimeWarning, match="compile"):
        assert jit_cache_size(Opaque()) is None
    # one-time warning: second call is silent
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error")
        assert jit_cache_size(Opaque()) is None


# ---------------------------------------------------------------------------
# speculative_summary: explicit fallback chain
# ---------------------------------------------------------------------------
def test_speculative_summary_bases():
    real = speculative_summary({"spec_drafted": 40, "spec_accepted": 30,
                                "spec_slot_rounds": 10})
    assert real["tokens_per_slot_round"] == pytest.approx(4.0)
    assert real["tokens_per_slot_round_basis"] == "spec_slot_rounds"
    legacy = speculative_summary({"spec_drafted": 40, "spec_accepted": 30},
                                 spec_k=4)
    assert legacy["tokens_per_slot_round"] == pytest.approx(4.0)
    assert legacy["tokens_per_slot_round_basis"] == "spec_k"
    assert legacy["acceptance_rate"] == pytest.approx(0.75)


def test_speculative_summary_unknown_basis_warns():
    with pytest.warns(RuntimeWarning, match="spec_slot_rounds"):
        out = speculative_summary({"spec_drafted": 40, "spec_accepted": 30})
    # explicit unknown — not zero, not a fabricated rate
    assert out["tokens_per_slot_round"] is None
    assert out["tokens_per_slot_round_basis"] is None
    assert out["spec_drafted"] == 40            # the drafts stay visible


def test_speculative_summary_no_speculation_is_silent():
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error")
        out = speculative_summary({})
    assert out["acceptance_rate"] is None
    assert out["tokens_per_slot_round"] is None


# ---------------------------------------------------------------------------
# tracer: spans, ring bounds, Chrome-trace schema
# ---------------------------------------------------------------------------
def test_tracer_spans_and_instants():
    t = [0.0]
    tr = Tracer(clock=lambda: t[0])
    with tr.span("tick", n=1):
        t[0] = 1.0
        with tr.device_span("decode_step"):
            t[0] = 3.0
        t[0] = 4.0
    tr.instant("quarantine", rid=7, detail="nan")
    tr.complete("queue_wait", 0.5, 2.5, rid=7)
    evs = tr.events()
    # inner span closes first
    inner, outer, inst, comp = evs
    assert (inner["name"], inner["ts"], inner["dur"]) == ("decode_step", 1.0, 2.0)
    assert (outer["name"], outer["ts"], outer["dur"]) == ("tick", 0.0, 4.0)
    assert outer["pid"] == HOST_PID and outer["args"] == {"n": 1}
    assert inst["ph"] == "i" and inst["pid"] == REQUEST_PID and inst["tid"] == 7
    assert comp["ph"] == "X" and comp["dur"] == pytest.approx(2.0)
    assert tr.request_timeline(7) == [comp, inst]   # sorted by timestamp


def test_tracer_ring_bounds():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr) == 4
    assert tr.total == 10 and tr.dropped == 6
    assert [e["name"] for e in tr.events()] == ["e6", "e7", "e8", "e9"]
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_chrome_trace_schema(tmp_path):
    t = [100.0]
    tr = Tracer(clock=lambda: t[0])
    with tr.span("tick"):
        t[0] = 100.001
    tr.instant("retire", rid=3, reason="max_tokens")
    doc = tr.to_chrome_trace()
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    names = {(e["name"], e["pid"]) for e in meta}
    assert ("process_name", HOST_PID) in names
    assert ("process_name", REQUEST_PID) in names
    assert ("thread_name", REQUEST_PID) in names    # request 3's track
    span = next(e for e in evs if e["ph"] == "X")
    assert span["ts"] == pytest.approx(0.0, abs=1e-6)      # µs from epoch
    assert span["dur"] == pytest.approx(1000.0, rel=1e-6)  # 1 ms -> 1000 µs
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t" and inst["tid"] == 3
    assert doc["otherData"]["total_events"] == 2
    # save() round-trips through json
    p = tr.save(str(tmp_path / "trace.json"))
    assert json.load(open(p))["traceEvents"]


def test_null_tracer_is_inert(tmp_path):
    assert not NULL_TRACER.enabled
    with NULL_TRACER.span("x"):
        with NULL_TRACER.device_span("y"):
            pass
    NULL_TRACER.instant("z", rid=1)
    NULL_TRACER.complete("w", 0.0, 1.0, rid=1)
    assert len(NULL_TRACER) == 0 and NULL_TRACER.events() == []
    assert NULL_TRACER.to_chrome_trace()["traceEvents"] == []


# ---------------------------------------------------------------------------
# scheduler integration: the trace reconstructs the measured numbers
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def traced_run(hyena_model):
    """One instrumented serving run shared by the reconstruction tests:
    5 requests through 2 slots with tracing + metrics on."""
    cfg, params = hyena_model
    tracer = Tracer()
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   tracer=tracer, events_limit=8)
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(_prompts(cfg.vocab), GEN_LENS)]
    eng.run()
    return eng, tracer, reqs


def test_trace_reconstructs_ttft_and_latency(traced_run):
    """queue_wait + prefill spans sum to the measured TTFT; the full span
    chain sums to the measured end-to-end latency — exactly, because the
    spans are emitted from the Request's own timestamps."""
    eng, tracer, reqs = traced_run
    for req in reqs:
        assert req.status == "finished"
        tl = tracer.request_timeline(req.rid)
        spans = {e["name"]: e for e in tl if e["ph"] == "X"}
        assert set(spans) == {"queue_wait", "prefill", "decode"}
        ttft = spans["queue_wait"]["dur"] + spans["prefill"]["dur"]
        assert ttft == pytest.approx(req.ttft, abs=1e-9)
        total = ttft + spans["decode"]["dur"]
        assert total == pytest.approx(req.latency, abs=1e-9)
        # contiguous: each stage starts where the previous ended
        assert spans["prefill"]["ts"] == pytest.approx(
            spans["queue_wait"]["ts"] + spans["queue_wait"]["dur"])
        retire = [e for e in tl if e["name"] == "retire"]
        assert len(retire) == 1
        assert retire[0]["args"]["reason"] == "max_tokens"


def test_host_loop_phase_spans_present(traced_run):
    eng, tracer, _ = traced_run
    host = {e["name"] for e in tracer.events() if e["pid"] == HOST_PID}
    assert {"dispatch", "retire", "admit", "decode_step", "prefill"} <= host


def test_metrics_populated_by_run(traced_run):
    eng, _, reqs = traced_run
    m = eng.metrics
    assert m.get("serve_requests_finished").value == len(reqs)
    assert m.get("serve_ttft_s").count == len(reqs)
    assert m.get("serve_request_latency_s").count == len(reqs)
    assert m.get("serve_tick_latency_s").count >= len(reqs)
    assert m.get("serve_decode_steps").value == eng.stats["decode_steps"]
    fill = m.get("serve_batch_fill_ratio")
    assert fill.count > 0 and 0.0 <= fill.percentile(50) <= 1.0
    # percentiles agree with the engine's own recorded latencies
    lats = sorted(r.latency for r in reqs)
    h = m.get("serve_request_latency_s")
    assert lats[0] - 1e-9 <= h.percentile(50) <= lats[-1] + 1e-9
    # the whole thing expounds without error
    assert "serve_ttft_s_count" in m.to_prometheus()
    json.dumps(m.snapshot())


def test_events_ring_is_bounded(hyena_model):
    """With events_limit=n the recovery log keeps the n newest events while
    the monotonic total and the serve_events_total counter keep counting."""
    cfg, params = hyena_model
    inj = FaultInjector([{"tick": t, "kind": "corrupt", "where": "state",
                          "value": float("nan")} for t in (3, 5, 7, 9)],
                        seed=0)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   fault_injector=inj, events_limit=2)
    for p, g in zip(_prompts(cfg.vocab), GEN_LENS):
        eng.submit(p, max_new_tokens=g)
    eng.run()
    assert eng._events_total >= 3               # one quarantine per corrupt
    assert len(eng.events) == 2                 # ring kept only the newest
    assert eng._events_total > len(eng.events)
    assert eng.metrics.get("serve_events_total").value == eng._events_total


def test_fault_recovery_lands_on_request_timeline(hyena_model):
    """A quarantined request's timeline shows the recovery instants — the
    trace answers 'why was this request slow'."""
    cfg, params = hyena_model
    tracer = Tracer()
    inj = FaultInjector([{"tick": 4, "kind": "corrupt", "where": "state",
                          "value": float("nan")}], seed=0)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   fault_injector=inj, tracer=tracer)
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(_prompts(cfg.vocab), GEN_LENS)]
    eng.run()
    assert eng.resilience.get("slot_reprefills") >= 1
    hit = [ev["rid"] for ev in eng.events
           if ev["kind"] == "quarantine" and "rid" in ev]
    assert hit
    tl = tracer.request_timeline(hit[0])
    kinds = {e["name"] for e in tl if e["ph"] == "i"}
    assert "quarantine" in kinds
    # the faulted request still has a complete lifecycle
    assert {e["name"] for e in tl if e["ph"] == "X"} \
        == {"queue_wait", "prefill", "decode"}
    for r in reqs:
        assert r.status in ("finished", "error")


def test_zero_steady_state_compiles_with_telemetry_on():
    """Tracing + metrics must not introduce tracing-unstable values into
    jitted code: after warmup, a fully instrumented serving run triggers no
    XLA compilation (the observability acceptance gate, unit-sized)."""
    cfg = _hyena_cfg("obs-compile-count")
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   tracer=Tracer(), overlap=True)
    eng.warmup(PROMPT_LENS)
    with count_compiles() as scope:
        for p, g in zip(_prompts(cfg.vocab), GEN_LENS):
            eng.submit(p, max_new_tokens=g)
        eng.run()
    assert scope.compiles == 0, "telemetry must stay off the device path"
    assert len(eng.finished) == len(GEN_LENS)
    assert len(eng.tracer) > 0                  # ... while actually tracing


# ---------------------------------------------------------------------------
# HTTP endpoint
# ---------------------------------------------------------------------------
def test_metrics_http_endpoint():
    reg = MetricsRegistry()
    reg.counter("serve_reqs").inc(5)
    tr = Tracer()
    tr.instant("tick")
    server = start_metrics_server(reg, 0, tracer=tr,
                                  extra=lambda: {"stats": {"ticks": 9}})
    try:
        port = server.server_address[1]
        base = f"http://127.0.0.1:{port}"
        text = urllib.request.urlopen(f"{base}/metrics").read().decode()
        assert "serve_reqs 5" in text
        doc = json.load(urllib.request.urlopen(f"{base}/metrics.json"))
        assert doc["metrics"]["serve_reqs"] == 5
        assert doc["stats"] == {"ticks": 9}
        trace = json.load(urllib.request.urlopen(f"{base}/trace.json"))
        assert any(e.get("name") == "tick" for e in trace["traceEvents"])
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{base}/nope")
    finally:
        server.shutdown()
