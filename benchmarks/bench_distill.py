"""Fig 5.2 / Table 5.2 analog: distillation error vs order, Hankel spectrum
decay, and wall time per filter."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from benchmarks.models import build, hyena_cfg
from repro.core import eval_filter, hankel_singular_values
from repro.core.distill import distill_filters
from repro.models.hyena import materialize_filters

L = 1024


def main(out):
    cfg = hyena_cfg()
    params = build(cfg)
    fp = jax.tree.map(lambda x: x[0], params["groups"]["l0"]["mix"]["filter"])
    h, _ = materialize_filters(fp, L, cfg.hyena)
    sv = hankel_singular_values(h)
    out(row("fig5.2/hankel_sigma16_over_sigma1", 0.0,
            f"ratio={float(jnp.max(sv[:, 16]/sv[:, 0])):.2e}"))
    for modes in (2, 4, 8, 16):
        t0 = time.time()
        ssm, _ = distill_filters(h, modes, steps=1000)
        dt = time.time() - t0
        err = jnp.linalg.norm(eval_filter(ssm, L) - h, axis=-1) / \
            jnp.linalg.norm(h, axis=-1)
        out(row(f"fig5.2/distill_order{2*modes}", dt * 1e6 / h.shape[0],
                f"rel_l2={float(jnp.max(err)):.3e}"))
