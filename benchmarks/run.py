"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig1.1] [--json out.json]

Prints ``name,us_per_call,derived`` CSV rows. Suites may additionally return
a structured metrics dict; --json collects those into one file (used by
`make bench-serve` to track the serving perf trajectory across PRs). All
models are width-reduced (CPU container); the comparison *structure* matches
the paper's figures.
"""
import argparse
import json
import sys
import traceback

sys.path.insert(0, "src")

from benchmarks import (bench_distill, bench_kernels, bench_memory,
                        bench_prefill_strategies, bench_prompt_scaling,
                        bench_state_dim, bench_throughput)

SUITES = {
    "fig1.1_throughput": bench_throughput.main,
    "serve_stream": bench_throughput.stream_main,
    "serve_chaos": bench_throughput.chaos_main,
    "fig5.3_prompt_scaling": bench_prompt_scaling.main,
    "fig5.4_memory": bench_memory.main,
    "sec5.4_state_dim": bench_state_dim.main,
    "sec3.4_prefill": bench_prefill_strategies.main,
    "fig5.2_distill": bench_distill.main,
    "kernels": bench_kernels.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--json", type=str, default=None,
                    help="write structured suite metrics to this file")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows = []
    data = {}

    def out(r):
        print(r, flush=True)
        rows.append(r)

    failures = 0
    for name, fn in SUITES.items():
        if args.only and args.only not in name:
            continue
        try:
            ret = fn(out)
            if isinstance(ret, dict):
                data.update(ret)
        except Exception:
            failures += 1
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
        print(f"[bench] wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
