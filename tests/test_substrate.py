"""Substrate: MoE equivalence, data determinism, checkpoint/restart,
optimizer behavior, gradient compression."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.data.pipeline import SyntheticLM
from repro.models.moe import init_moe, moe_dense, moe_dropless
from repro.optim.adamw import adamw_init, adamw_update, cosine_schedule
from repro.train.checkpoint import Checkpointer


@pytest.mark.slow
def test_moe_dense_equals_dropless():
    """The two MoE implementations are numerically equivalent."""
    mcfg = MoEConfig(n_experts=8, top_k=2)
    params = init_moe(jax.random.PRNGKey(0), 32, 64, mcfg)
    from repro.distributed.sharding import unzip
    params, _ = unzip(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y1, a1 = moe_dense(params, x, mcfg)
    y2, a2 = moe_dropless(params, x, mcfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-4)


@pytest.mark.slow
def test_moe_dropless_grads_flow():
    mcfg = MoEConfig(n_experts=4, top_k=2)
    from repro.distributed.sharding import unzip
    params, _ = unzip(init_moe(jax.random.PRNGKey(0), 16, 32, mcfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))

    def loss(p):
        y, aux = moe_dropless(p, x, mcfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(params)
    assert all(bool(jnp.any(v != 0)) for k, v in
               [("wi", g["wi"]), ("wo", g["wo"]), ("router", g["router"])])


def test_synthetic_data_deterministic_and_step_indexed():
    src = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=3)
    b1 = src.batch(7)
    b2 = src.batch(7)
    b3 = src.batch(8)
    assert (b1 == b2).all()
    assert not (b1 == b3).all()
    assert b1.shape == (4, 17) and b1.min() >= 0 and b1.max() < 100


def test_checkpoint_atomicity_and_restore(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": [jnp.ones(4), jnp.zeros(2)]}
    ck.save(5, tree, blocking=True)
    ck.save(10, jax.tree.map(lambda x: x + 1, tree), blocking=False)
    ck.wait()
    ck.save(15, jax.tree.map(lambda x: x + 2, tree), blocking=True)
    assert ck.all_steps() == [10, 15]        # keep=2 GC'd step 5
    restored, step = ck.restore(tree)
    assert step == 15
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]) + 2)
    # interrupted write (.tmp dir) must not count as a checkpoint
    os.makedirs(tmp_path / "step_000000020.tmp")
    assert ck.latest_step() == 15


@pytest.mark.slow
def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    sched = cosine_schedule(0.5, warmup=5, total=200)
    for i in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(g, opt, params, lr=sched(i),
                                      weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clipping():
    from repro.optim.adamw import clip_by_global_norm
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert abs(float(total) - 1.0) < 1e-4
    assert float(norm) > 100.0


@pytest.mark.slow
def test_train_restart_resumes(tmp_path):
    """Injected failure + restart completes training deterministically."""
    from repro.configs import get_config, smoke_config
    from repro.distributed.sharding import unzip
    from repro.models.model import init_params
    from repro.train.loop import train
    from repro.train.train_step import init_opt, make_train_step
    from repro.data.pipeline import make_batches

    cfg = smoke_config(get_config("llama3.2-3b")).replace(
        vocab=64, n_layers=2, d_model=32, d_ff=64, n_heads=2, n_kv_heads=1,
        head_dim=16)
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    opt = init_opt(params)
    src = SyntheticLM(vocab=64, seq_len=16, global_batch=2, seed=1)
    step_fn = jax.jit(make_train_step(cfg, None, remat="none", warmup=2,
                                      total_steps=30))
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(RuntimeError):
        train(step_fn, params, opt, make_batches(src), steps=30, ckpt=ck,
              ckpt_every=5, log_every=100, fail_at_step=12)
    assert ck.latest_step() == 12            # final save in the crash handler
    out = train(step_fn, params, opt,
                make_batches(src, start_step=ck.latest_step() + 1),
                steps=30, ckpt=ck, ckpt_every=5, log_every=100)
    assert out["step"] == 29


def test_gradient_compression_roundtrip():
    from repro.train.train_step import make_train_step
    # int8 symmetric quantization error is bounded by scale/2
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    s = jnp.max(jnp.abs(x)) / 127.0
    q = jnp.round(x / s).astype(jnp.int8)
    err = jnp.max(jnp.abs(q.astype(jnp.float32) * s - x))
    assert float(err) <= float(s) / 2 + 1e-6
