"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). collective_bytes is
parsed from the post-SPMD HLO text: we sum the result bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
weighting all-reduce x2 (ring reduce+broadcast). cost_analysis numbers are
PER-PARTICIPANT after SPMD partitioning (the module is the per-device
program), so the terms are per-chip step latencies already — no extra /chips
division is applied to the parsed per-device quantities; the formulas above
are implemented with chips=1 against the per-device module.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

# bytes moved on the wire per byte of result, simple ring model
_COLL_WEIGHT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Total wire bytes per device and a per-op-kind breakdown."""
    per_kind: Dict[str, float] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        # skip the -done halves of async pairs (counted at -start)
        span = hlo_text[max(0, m.start() - 120):m.end()]
        if f"{kind}-done" in span:
            continue
        b = _shape_bytes(dtype, dims) * _COLL_WEIGHT[kind]
        per_kind[kind] = per_kind.get(kind, 0.0) + b
    return sum(per_kind.values()), per_kind


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, float]
    model_flops: float
    bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / HW["peak_flops_bf16"]

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HW["hbm_bw"]

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / HW["ici_bw"]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs per device (remat/redundancy waste)."""
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline the step achieves if every term
        overlapped perfectly: t_model_compute / max(all terms)."""
        t_model = self.model_flops / HW["peak_flops_bf16"]
        t = max(self.t_compute, self.t_memory, self.t_collective, 1e-30)
        return t_model / t

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} "
                f"| {self.t_compute*1e3:.2f} | {self.t_memory*1e3:.2f} "
                f"| {self.t_collective*1e3:.2f} | {self.bottleneck} "
                f"| {self.useful_ratio:.2f} | {self.roofline_fraction:.2%} |")


def analyze(compiled, *, arch: str, shape: str, mesh_name: str,
            model_flops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):            # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    cb, breakdown = collective_bytes(text)
    try:
        ma = compiled.memory_analysis()
        per_dev = float(getattr(ma, "argument_size_in_bytes", 0) +
                        getattr(ma, "output_size_in_bytes", 0) +
                        getattr(ma, "temp_size_in_bytes", 0))
    except Exception:
        per_dev = None
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, hlo_flops=flops,
                    hlo_bytes=byts, coll_bytes=cb, coll_breakdown=breakdown,
                    model_flops=model_flops, bytes_per_device=per_dev)


def fused_memory_bytes(cfg, shape, n_chips: int = 256) -> float:
    """Analytic per-chip HBM traffic for a step, assuming TPU-level fusion.

    XLA:CPU's "bytes accessed" counts every op's operands with no fusion, so
    the raw memory term is a loose upper bound (flash-attention block buffers
    and elementwise chains live in VMEM on TPU). This model counts only
    irreducible traffic:

      train:   params: read bf16 (fwd+bwd+remat=3x) + grad write/read fp32 +
               AdamW m,v read+write fp32 + fp32 master read/write
               activations: saved layer inputs (B,S,D) bf16 x layers, written
               once + read once; logits fp32 read/write twice.
      prefill: params read once + kv cache write + activations stream ~2x.
      decode:  active params read + cache/state read+write + small vectors.
    """
    P = cfg.n_params()
    Pa = cfg.n_active_params()
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.n_layers
    if shape.kind == "train":
        # full bf16 params stream through each chip (post all-gather) for
        # fwd + remat + bwd; optimizer state + grads + master touch only the
        # local 1/n shard
        param_traffic = Pa * 2 * 3 + P * (4 + 4 + 2 * 4 + 2 * 4) / n_chips
        act = B * S * D * 2 * L * 2 * 2 / n_chips   # saved inputs w+r, fwd+bwd
        logits = B * S * cfg.vocab * 4 * 2 / n_chips
        return param_traffic + act + logits
    if shape.kind == "prefill":
        kv = 2 * B * S * cfg.n_kv_heads * cfg.hd * 2 * L / n_chips
        act = B * S * D * 2 * L * 2 / n_chips
        return Pa * 2 / min(n_chips, 16) + kv + act   # TP-16 param shards
    # decode: one token
    kv_read = (2 * B * S * cfg.n_kv_heads * cfg.hd * 2 * L / n_chips
               if not cfg.attention_free else 0)
    state = B * D * 64 * L * 4 * 2 / n_chips    # generous recurrent-state bound
    return Pa * 2 / min(n_chips, 16) + kv_read + state


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D for training, 2 N_active per generated token for
    decode, 2 N_active * tokens for prefill — per device."""
    n_active = cfg.n_active_params()
    toks = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        return 2.0 * n_active * toks
    return 2.0 * n_active * shape.global_batch      # decode: one token/stream
