"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel ships as a triple:
  <name>/<name>.py — pl.pallas_call with explicit BlockSpec VMEM tiling
  <name>/ops.py    — jit'd public wrapper (interpret mode on CPU)
  <name>/ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels (TPU adaptations of the paper's inference hot-spots):
  modal_filter    — materialize distilled filters h[t] = Re sum R lam^(t-1)
                    (Lemma 3.1 O(dL) evaluation): the basis powers are
                    generated blockwise in VMEM and contracted over modes.
  ssm_decode      — fused modal-SSM decode step across channels (Prop. 3.3):
                    state update + output reduction in one HBM pass. The
                    decode step is purely memory-bound (state ~ B*D*d), so
                    fusing the 5 elementwise/reduce stages is the win.
  flash_attention — blocked causal GQA attention with online softmax (VMEM
                    tiles, MXU-aligned block shapes). Used by the attention
                    baselines the paper benchmarks against.
"""
from repro.kernels.modal_filter import ops as modal_filter_ops  # noqa: F401
from repro.kernels.ssm_decode import ops as ssm_decode_ops      # noqa: F401
from repro.kernels.flash_attention import ops as flash_attention_ops  # noqa: F401
