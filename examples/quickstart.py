"""Quickstart: the LaughingHyena pipeline in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. build a small MultiHyena LM and train it briefly on synthetic data
2. inspect the Hankel spectrum of its long filters (pick the order)
3. distill every filter into a modal SSM (LaughingHyena)
4. generate auto-regressively in O(d)-per-token recurrent mode
5. confirm the distilled model's logits match the convolutional forward
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.core.distill import distill_model
from repro.core.hankel import hankel_singular_values, suggest_order
from repro.data.pipeline import SyntheticLM
from repro.distributed.sharding import unzip
from repro.models.hyena import materialize_filters
from repro.models.model import forward, init_params
from repro.serve.engine import GenerationEngine
from repro.train.train_step import init_opt, make_train_step

# 1. ----------------------------------------------------------------- train
cfg = smoke_config(get_config("multihyena-153m")).replace(dtype="float32",
                                                          vocab=256)
params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
opt = init_opt(params)
src = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0)
step = jax.jit(make_train_step(cfg, None, base_lr=2e-3, warmup=10,
                               total_steps=200, remat="none"))
for i in range(200):
    params, opt, m = step(params, opt, {"tokens": jnp.asarray(src.batch(i))},
                          jnp.asarray(i))
    if i % 50 == 0:
        print(f"step {i:4d}  loss {float(m['loss']):.3f}")

# 2. ------------------------------------------------------- Hankel analysis
fp = jax.tree.map(lambda x: x[0], params["groups"]["l0"]["mix"]["filter"])
h, _ = materialize_filters(fp, 256, cfg.hyena)
sv = hankel_singular_values(h)
print("suggested distillation orders (tol 1e-2):",
      [int(x) for x in suggest_order(sv, 1e-2)])

# 3. ----------------------------------------------------------- distillation
params_d, errs = distill_model(params, cfg, steps=2000, L=256)
print("per-filter rel l2 distillation errors:",
      jax.tree.map(lambda e: [float(x) for x in e.ravel()], errs))

# 4./5. ------------------------------------------------ recurrent generation
prompt = jnp.asarray(src.batch(999))[:2, :32]
logits_conv, _ = forward(params_d, prompt, cfg)
eng = GenerationEngine(params_d, cfg, max_len=64)
toks, info = eng.generate(jax.random.PRNGKey(1), prompt, 8, temperature=0.0)
print("generated:", toks[0].tolist())
print(f"recurrent state memory: {info['cache_bytes']/1e3:.1f} KB (constant in "
      "generated length)")
