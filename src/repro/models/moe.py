"""Mixture-of-Experts MLP (token-choice top-k).

Two implementations:
  * "dense"    — every expert computed for every token, combined with routing
                 weights. Simple, numerically identical, but inflates FLOPs by
                 n_experts/top_k (visible in the roofline's HLO/model ratio).
  * "dropless" — sort-based dispatch with jax.lax.ragged_dot (MegaBlocks-style
                 dropless MoE). FLOPs proportional to active experts.

Expert weights carry the ("expert", "embed", "mlp") logical axes so the
sharding rules place experts on the TP axis when divisible (dbrx: 16/16) and
otherwise shard the per-expert mlp dim (granite: 40 experts, d_ff/16).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Param
from repro.models.layers import NOCTX, ShardCtx, dense_init


def init_moe(key, d: int, f: int, moe_cfg):
    E = moe_cfg.n_experts
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "router": dense_init(k1, (d, E), ("embed", None), in_dim=d),
        # gate and up fused on last axis: (E, d, 2f)
        "wi": dense_init(k2, (E, d, 2 * f), ("expert", "embed", "mlp"), in_dim=d),
        "wo": dense_init(k3, (E, f, d), ("expert", "mlp", "embed"), in_dim=f),
    }


def _route(params, x2, moe_cfg):
    """x2: (T, d) -> (weights (T,k), idx (T,k), aux losses)."""
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, moe_cfg.top_k)
    w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
    # aux: load-balance (Switch) + router z-loss
    E = probs.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E), axis=0)
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = moe_cfg.load_balance_loss * lb + moe_cfg.router_z_loss * z
    return w, idx, aux


def moe_dense(params, x, moe_cfg, *, ctx: ShardCtx = NOCTX):
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    w, idx, aux = _route(params, x2, moe_cfg)
    E = moe_cfg.n_experts
    f = params["wo"].shape[1]
    h = jnp.einsum("td,edf->tef", x2, params["wi"].astype(x.dtype))
    h = jax.nn.silu(h[..., :f]) * h[..., f:]
    y_all = jnp.einsum("tef,efd->ted", h, params["wo"].astype(x.dtype))
    mask = jnp.zeros((B * S, E), x.dtype)
    mask = jax.vmap(lambda m, i, ww: m.at[i].add(ww))(mask, idx, w.astype(x.dtype))
    y = jnp.einsum("ted,te->td", y_all, mask)
    return y.reshape(B, S, d), aux


def moe_dropless(params, x, moe_cfg, *, ctx: ShardCtx = NOCTX):
    B, S, d = x.shape
    T = B * S
    k = moe_cfg.top_k
    E = moe_cfg.n_experts
    f = params["wo"].shape[1]
    x2 = x.reshape(T, d)
    w, idx, aux = _route(params, x2, moe_cfg)

    flat_expert = idx.reshape(T * k)
    order = jnp.argsort(flat_expert)                       # (T*k,)
    tok = order // k
    xs = jnp.take(x2, tok, axis=0)                         # (T*k, d)
    gs = jnp.bincount(flat_expert, length=E)

    h = jax.lax.ragged_dot(xs, params["wi"].astype(x.dtype), gs)
    h = jax.nn.silu(h[:, :f]) * h[:, f:]
    h = ctx.cs(h, ("batch", "mlp"))
    o = jax.lax.ragged_dot(h, params["wo"].astype(x.dtype), gs)
    wflat = jnp.take(w.reshape(T * k), order).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(o * wflat[:, None])
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Expert parallelism under shard_map ("ep" impl).
#
# The GSPMD dropless path sorts a *globally sharded* token array: XLA
# all-gathers the full token set to sort it (measured ~47 TB of collectives
# per step for dbrx/train_4k). Here routing and dispatch are fully LOCAL:
# the residual stream is batch-sharded over 'data' and replicated over
# 'model'; each model-rank owns E/TP experts, selects its own tokens with a
# capacity limit, runs its experts, and a single psum over 'model' combines
# expert outputs. Collectives per layer: one (B_loc, S, D) all-reduce —
# identical in shape to the TP mlp all-reduce of a dense model.
# ---------------------------------------------------------------------------
def moe_expert_parallel(params, x, moe_cfg, *, ctx: ShardCtx = NOCTX,
                        capacity_factor: float = 1.25):
    from repro.distributed.sharding import resolve_spec, shard_map_compat
    mesh = ctx.mesh
    E = moe_cfg.n_experts
    if mesh is None:
        return moe_dropless(params, x, moe_cfg, ctx=ctx)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = mesh_shape.get("model", 1)
    if E % tp != 0:
        # experts don't tile the TP axis (granite: 40 on 16): run the local
        # dropless path with weights gathered inside the shard (they are
        # small: E * 3 * d * f_small), tokens sharded over ALL axes.
        return _moe_local_dropless(params, x, moe_cfg, ctx=ctx)
    B, S, d = x.shape
    k = moe_cfg.top_k
    f = params["wo"].shape[-2]
    spec_x = resolve_spec((B, S, d), ("batch", None, None), ctx.rules,
                          mesh_shape)
    spec_wi = resolve_spec(params["wi"].shape, ("expert", None, None),
                           ctx.rules, mesh_shape)
    spec_wo = resolve_spec(params["wo"].shape, ("expert", None, None),
                           ctx.rules, mesh_shape)
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec_x))
    E_loc = E // tp

    def local(x_blk, wr, wi_blk, wo_blk):
        Bl, Sl, _ = x_blk.shape
        T = Bl * Sl
        cap = int(capacity_factor * T * k / E) + 1
        x2 = x_blk.reshape(T, d)
        w, idx, aux = _route({"router": wr}, x2, moe_cfg)   # local routing
        my0 = jax.lax.axis_index("model") * E_loc
        y = jnp.zeros((T, d), x_blk.dtype)
        flat_e = idx.reshape(T * k)
        flat_w = w.reshape(T * k)
        tok_of = jnp.arange(T * k) // k
        for j in range(E_loc):
            e = my0 + j
            mine = flat_e == e
            # stable capacity selection: assigned slots first, then padding
            order = jnp.argsort(jnp.where(mine, jnp.arange(T * k),
                                          jnp.inf))[:cap]
            valid = jnp.take(mine, order)
            toks = jnp.take(tok_of, order)
            xs = jnp.take(x2, toks, axis=0)                 # (cap, d)
            h = xs @ wi_blk[j].astype(x_blk.dtype)
            h = jax.nn.silu(h[:, :f]) * h[:, f:]
            o = h @ wo_blk[j].astype(x_blk.dtype)
            scale = (jnp.take(flat_w, order) * valid).astype(x_blk.dtype)
            y = y.at[toks].add(o * scale[:, None])
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, "model")
        if "data" in mesh_shape:
            aux = jax.lax.pmean(aux, "data")
        if "pod" in mesh_shape:
            aux = jax.lax.pmean(aux, "pod")
        return y.reshape(Bl, Sl, d), aux

    y, aux = shard_map_compat(
        local, mesh,
        (spec_x, resolve_spec(params["router"].shape, (None, None),
                              ctx.rules, mesh_shape), spec_wi, spec_wo),
        (spec_x, jax.sharding.PartitionSpec()),
    )(x, params["router"], params["wi"], params["wo"])
    return y, aux


def _moe_local_dropless(params, x, moe_cfg, *, ctx: ShardCtx = NOCTX):
    """Tokens sharded over every mesh axis; expert weights all-gathered into
    each shard (cheap when per-expert d_ff is small); routing/sort fully
    local — zero data collectives beyond the weight gather."""
    try:
        from jax import shard_map            # jax >= 0.8
    except ImportError:                      # pragma: no cover
        from repro.distributed.sharding import resolve_spec, shard_map_compat
    mesh = ctx.mesh
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    B, S, d = x.shape
    # batch over ('data','model') when divisible, else data only
    axes = ("batch", "qseq", None)
    spec_x = resolve_spec((B, S, d), axes, ctx.rules, mesh_shape)
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec_x))
    P0 = jax.sharding.PartitionSpec()

    def local(x_blk, wr, wi, wo):
        y, aux = moe_dropless({"router": wr, "wi": wi, "wo": wo}, x_blk,
                              moe_cfg)
        for ax in mesh_shape:
            aux = jax.lax.pmean(aux, ax)
        return y, aux

    y, aux = shard_map_compat(local, mesh, (spec_x, P0, P0, P0),
                              (spec_x, P0))(
        x, params["router"], params["wi"], params["wo"])
    return y, aux


def moe_block(params, x, moe_cfg, *, impl: str = "dropless",
              ctx: ShardCtx = NOCTX) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if impl == "dense":
        return moe_dense(params, x, moe_cfg, ctx=ctx)
    if impl == "ep":
        return moe_expert_parallel(params, x, moe_cfg, ctx=ctx)
    return moe_dropless(params, x, moe_cfg, ctx=ctx)
