"""Gradient-based modal interpolation (paper Sec. 3.2, App. B, D.2).

Fits the modal form to target filters by unconstrained AdamW on the l2 (time
domain) or H2 (frequency domain; equal by Parseval, kept for faithfulness)
discrepancy. Initialization is either random (paper) or Kung/Ho-Kalman —
SVD of the Hankel matrix, shift-invariance for the poles, then a *linear*
least-squares solve for the residues (the "two linear problems" view of
Prony's method the paper cites; used here as a warm start that cuts the
number of gradient steps by ~10x, see EXPERIMENTS.md).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hankel import hankel_matrix
from repro.core.modal import ModalSSM, eval_filter, init_modal
from repro.optim.adamw import adamw_init, adamw_update, cosine_schedule


# ---------------------------------------------------------------------------
# Kung / Ho-Kalman initialization
# ---------------------------------------------------------------------------
def kung_poles(h: jnp.ndarray, d: int) -> jnp.ndarray:
    """Estimate d modal poles from a filter h (..., L) via Hankel-SVD
    shift-invariance (App. E.3.2 steps 1-2 / Kung's method).

    The modal form takes Re[sum R lam^t], so one pole per conjugate pair
    suffices: we extract 2d eigenvalues from the order-2d balanced factor,
    keep ONE representative per conjugate pair (Im >= 0; eigenvalues of the
    real shift matrix come in conjugate pairs, so folding |theta| would
    duplicate each pole and crowd out the weak true modes), and rank by the
    h-inf influence |R| / |1 - |lam|| after a linear residue fit.
    """
    S = hankel_matrix(h).astype(jnp.float32)
    m = S.shape[-1]
    dd = min(2 * d, m - 1)
    U, s, _ = jnp.linalg.svd(S, full_matrices=False)
    Od = U[..., :, :dd] * jnp.sqrt(s[..., None, :dd] + 1e-30)
    O1 = Od[..., :-1, :]
    O2 = Od[..., 1:, :]
    A = jnp.linalg.pinv(O1) @ O2                           # (..., 2d, 2d)
    lam = jnp.linalg.eigvals(A)
    mag = jnp.clip(jnp.abs(lam), 1e-4, 1.2)
    ang = jnp.angle(lam)
    # jitter the phases so coincident true poles don't make the LSQ singular
    jitter = jnp.linspace(0.0, 1e-4, dd)
    lam = mag * jnp.exp(1j * (ang + jitter))
    upper = ang >= -1e-6            # one per conjugate pair; real poles kept
    # lower-half duplicates are swapped for negligible decoy poles so the
    # residue solve attributes each pair's energy to its single representative
    decoy = 1e-3 * jnp.exp(1j * jnp.linspace(0.1, 3.0, dd))
    lam = jnp.where(upper, lam, decoy)
    R = fit_residues(lam, h)
    infl = jnp.abs(R) / jnp.clip(jnp.abs(1.0 - jnp.abs(lam)), 1e-6)
    infl = jnp.where(upper, infl, -1.0)
    idx = jnp.argsort(-infl, axis=-1)[..., :d]
    return jnp.take_along_axis(lam, idx, axis=-1)


def fit_residues(lam: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Given poles, residues solve a LINEAR least-squares problem.

    Re[V R] ~= h[1:], where V[t, n] = lam_n^t (t = 0..L-2). Solved via the
    real-stacked normal equations. lam: (..., d); h: (..., L)."""
    L = h.shape[-1]
    t = jnp.arange(L - 1, dtype=jnp.float32)
    logl = jnp.log(jnp.clip(jnp.abs(lam), 1e-8))
    ang = jnp.angle(lam)
    mag = jnp.exp(logl[..., None, :] * t[:, None])         # (..., L-1, d)
    Vr = mag * jnp.cos(ang[..., None, :] * t[:, None])
    Vi = -mag * jnp.sin(ang[..., None, :] * t[:, None])
    # design matrix for x = [R_re; R_im]: h ~ Vr R_re + Vi R_im
    X = jnp.concatenate([Vr, Vi], axis=-1)                 # (..., L-1, 2d)
    XtX = jnp.einsum("...ti,...tj->...ij", X, X)
    Xty = jnp.einsum("...ti,...t->...i", X, h[..., 1:])
    d2 = X.shape[-1]
    # scale-aware ridge keeps the system SPD even with (near-)duplicate poles
    scale = jnp.trace(XtX, axis1=-2, axis2=-1)[..., None, None] / d2
    sol = jnp.linalg.solve(XtX + 1e-6 * scale * jnp.eye(d2),
                           Xty[..., None])[..., 0]
    d = lam.shape[-1]
    return sol[..., :d] + 1j * sol[..., d:]


def kung_init(h: jnp.ndarray, d: int) -> ModalSSM:
    lam = kung_poles(h, d)
    R = fit_residues(lam, h)
    return ModalSSM(
        log_a=jnp.log(jnp.clip(jnp.abs(lam), 1e-8)).astype(jnp.float32),
        theta=jnp.angle(lam).astype(jnp.float32),
        R_re=jnp.real(R).astype(jnp.float32),
        R_im=jnp.imag(R).astype(jnp.float32),
        h0=h[..., 0].astype(jnp.float32),
    )


# ---------------------------------------------------------------------------
# Distillation losses
# ---------------------------------------------------------------------------
def l2_loss(ssm: ModalSSM, h: jnp.ndarray) -> jnp.ndarray:
    """Time-domain squared-l2 interpolation error (per filter, summed)."""
    hh = eval_filter(ssm, h.shape[-1])
    return jnp.sum(jnp.square(hh[..., 1:] - h[..., 1:]))


def h2_loss(ssm: ModalSSM, h: jnp.ndarray) -> jnp.ndarray:
    """H2 (DFT-domain) error — equals l2 by Parseval; kept for Sec. 3.1."""
    hh = eval_filter(ssm, h.shape[-1])
    F1 = jnp.fft.rfft(hh, axis=-1)
    F2 = jnp.fft.rfft(h, axis=-1)
    return jnp.sum(jnp.abs(F1 - F2) ** 2) / h.shape[-1]


# ---------------------------------------------------------------------------
# Distillation driver
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("d", "steps", "objective", "init"))
def distill_filters(h: jnp.ndarray, d: int, *, steps: int = 3000,
                    lr: float = 3e-3, objective: str = "l2",
                    init: str = "kung", key: Optional[jnp.ndarray] = None
                    ) -> Tuple[ModalSSM, jnp.ndarray]:
    """Distill filters h (..., L) into order-d modal SSMs.

    Returns (ssm, per-step loss trace). AdamW + cosine decay (paper D.2 uses
    AdamW 3e-4 with cosine annealing; we default to Kung warm start + a
    shorter schedule, which reaches the same error earlier).
    """
    h = h.astype(jnp.float32)
    if key is None:
        key = jax.random.PRNGKey(0)
    if init == "kung":
        ssm = kung_init(h, d)
    else:
        ssm = init_modal(key, h.shape[:-1], d)
        ssm = ssm._replace(h0=h[..., 0].astype(jnp.float32))
    loss_fn = l2_loss if objective == "l2" else h2_loss

    fit = {"log_a": ssm.log_a, "theta": ssm.theta,
           "R_re": ssm.R_re, "R_im": ssm.R_im}
    opt = adamw_init(fit)
    sched = cosine_schedule(lr, warmup=max(steps // 50, 1), total=steps,
                            final_frac=1e-3)

    def total_loss(f):
        return loss_fn(ModalSSM(f["log_a"], f["theta"], f["R_re"], f["R_im"],
                                ssm.h0), h)

    def step(carry, i):
        f, o = carry
        loss, g = jax.value_and_grad(total_loss)(f)
        f, o, _ = adamw_update(g, o, f, lr=sched(i), weight_decay=0.0,
                               max_norm=None)
        return (f, o), loss

    (fit, _), trace = jax.lax.scan(step, (fit, opt), jnp.arange(steps))
    out = ModalSSM(fit["log_a"], fit["theta"], fit["R_re"], fit["R_im"], ssm.h0)
    return out, trace


def distill_model(params, cfg, *, d: Optional[int] = None, steps: int = 3000,
                  objective: str = "l2", init: str = "kung", L: Optional[int] = None):
    """Distill every Hyena filter of a model in-place (returns new params).

    Materializes each layer's filters at length L (default cfg.max_seq capped
    at 8192 — pre-trained filters decay to ~0 well before that, App. D), fits
    modal SSMs, and writes them into params[...]["distilled"] in the layout
    hyena_decode expects. The passthrough absorbs the explicit Hyena bias:
    h0_total = h[0] + bias (both act as delta terms in the block).
    """
    from repro.models.hyena import materialize_filters
    from repro.configs.base import HYENA

    hcfg = cfg.hyena
    # `d` is the paper's order (real state dim); the modal form stores d/2
    # conjugate-pair representatives (App. B.1).
    d = (d or hcfg.distill_order) // 2
    L = L or min(cfg.max_seq, 8192)
    n_groups = cfg.n_layers // len(cfg.pattern)

    def distill_entry(block_params):
        h, bias = materialize_filters(block_params["filter"], L, hcfg)
        ssm, trace = distill_filters(h, d, steps=steps, objective=objective,
                                     init=init)
        dp = {
            "log_a": ssm.log_a, "theta": ssm.theta,
            "R_re": ssm.R_re, "R_im": ssm.R_im,
            "h0": ssm.h0 + bias,
        }
        err = jnp.sqrt(jnp.sum((eval_filter(ssm, L) - h) ** 2, -1) /
                       jnp.sum(h * h, -1).clip(1e-30))
        return dp, err

    new_params = jax.tree.map(lambda x: x, params)   # shallow copy
    errs = {}
    for i, kind in enumerate(cfg.pattern):
        if kind != HYENA:
            continue
        gp = params["groups"][f"l{i}"]["mix"]
        # vmap over the stacked group axis
        dp, err = jax.vmap(distill_entry)(gp)
        new_params["groups"][f"l{i}"]["mix"]["distilled"] = dp
        errs[f"l{i}"] = err
    return new_params, errs


def distillation_certificate(params, cfg, L: Optional[int] = None) -> Dict:
    """Measured per-layer distillation-error certificate for a distilled
    model: materialize every Hyena layer's TRUE filters and the distilled
    modal reconstruction at horizon L and record the worst-case gap.

    Per layer, ``l1`` = sum over positions of the max-over-filter error —
    the error any single conv output can accumulate over an L-token
    generation through that layer; ``max_abs`` is the worst single
    position. ``total_l1`` sums the layers and is what the serving drift
    gate (benchmarks/check_regression.py --drift) scales into a bound on
    steady-state logits divergence. The stored distilled passthrough
    absorbed the explicit bias (h0_total = h[0] + bias), so the bias is
    subtracted back out before comparing against the raw filters. Returns
    plain floats (JSON-ready).
    """
    from repro.models.hyena import materialize_filters
    from repro.configs.base import HYENA

    hcfg = cfg.hyena
    L = L or min(cfg.max_seq, 8192)
    layers: Dict[str, Dict[str, float]] = {}
    total = 0.0

    def entry_err(block_params):
        h, bias = materialize_filters(block_params["filter"], L, hcfg)
        dp = block_params["distilled"]
        ssm = ModalSSM(dp["log_a"], dp["theta"], dp["R_re"], dp["R_im"],
                       dp["h0"] - bias)
        return jnp.abs(eval_filter(ssm, L) - h)

    for i, kind in enumerate(cfg.pattern):
        if kind != HYENA:
            continue
        gp = params["groups"][f"l{i}"]["mix"]
        if "distilled" not in gp:
            raise ValueError("distillation_certificate requires distilled "
                             "params (run distill_model first)")
        err = jax.vmap(entry_err)(gp)               # (G, filters..., L)
        per_pos = jnp.max(err.reshape(-1, L), axis=0)
        l1 = float(jnp.sum(per_pos))
        layers[f"l{i}"] = {"max_abs": float(jnp.max(err)), "l1": l1}
        total += l1
    return {"layers": layers, "total_l1": total, "horizon": int(L)}
