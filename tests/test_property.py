"""Property-based tests (hypothesis) on system invariants.

The whole module is skipped cleanly when `hypothesis` isn't installed
(it's a dev-only dependency; see requirements-dev.txt).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import eval_filter, init_modal
from repro.core.modal import ModalSSM, modal_step
from repro.core.prefill import prefill_recurrent, prefill_vandermonde
from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES, resolve_spec)

MESH = {"data": 16, "model": 16}
MESH3 = {"pod": 2, "data": 16, "model": 16}

_dims = st.integers(min_value=1, max_value=4096)
_ax = st.sampled_from([None, "batch", "embed", "mlp", "heads", "kv_heads",
                       "vocab", "expert", "state", "kv_seq", "qseq"])


@given(st.lists(st.tuples(_dims, _ax), min_size=1, max_size=5),
       st.sampled_from([MESH, MESH3]))
@settings(max_examples=200, deadline=None)
def test_resolve_spec_always_valid(dims_axes, mesh):
    """Sharding resolution never assigns a mesh axis twice and always
    divides the dimension evenly — for arbitrary shapes."""
    shape = tuple(d for d, _ in dims_axes)
    axes = tuple(a for _, a in dims_axes)
    for rules in (TRAIN_RULES, SERVE_RULES):
        spec = resolve_spec(shape, axes, rules, mesh)
        used = []
        for dim, s in zip(shape, tuple(spec)):
            if s is None:
                continue
            flat = s if isinstance(s, tuple) else (s,)
            used.extend(flat)
            size = int(np.prod([mesh[a] for a in flat]))
            assert dim % size == 0
        assert len(used) == len(set(used))


@given(st.integers(min_value=1, max_value=6), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_stable_filter_decays(d, seed):
    """|lam| < 1 ==> the materialized filter's tail decays (stability)."""
    ssm = init_modal(jax.random.PRNGKey(seed), (1,), d, r_minmax=(0.2, 0.9))
    h = np.asarray(eval_filter(ssm, 512))[0]
    head = np.abs(h[1:64]).max() + 1e-12
    tail = np.abs(h[-32:]).max()
    assert tail < head * 0.9 + 1e-6


@given(st.integers(0, 2 ** 31 - 1), st.floats(0.1, 4.0), st.floats(0.1, 4.0))
@settings(max_examples=25, deadline=None)
def test_recurrence_is_linear_in_input(seed, a, b):
    """y(a*u1 + b*u2) == a*y(u1) + b*y(u2) for the SSM map (superposition)."""
    key = jax.random.PRNGKey(seed)
    ssm = init_modal(key, (1,), 4, r_minmax=(0.3, 0.9))
    u1 = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 32))
    u2 = jax.random.normal(jax.random.PRNGKey(seed + 2), (1, 32))
    x1 = prefill_recurrent(ssm, u1)
    x2 = prefill_recurrent(ssm, u2)
    x12 = prefill_recurrent(ssm, a * u1 + b * u2)
    np.testing.assert_allclose(np.asarray(a * x1 + b * x2), np.asarray(x12),
                               atol=1e-3, rtol=1e-3)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8), st.integers(8, 96))
@settings(max_examples=25, deadline=None)
def test_prefill_equivalence_property(seed, d, T):
    ssm = init_modal(jax.random.PRNGKey(seed), (2,), d, r_minmax=(0.2, 0.93))
    u = jax.random.normal(jax.random.PRNGKey(seed + 9), (2, T))
    xa = prefill_recurrent(ssm, u)
    xb = prefill_vandermonde(ssm, u)
    scale = float(jnp.max(jnp.abs(xa))) + 1e-6
    assert float(jnp.max(jnp.abs(xa - xb))) / scale < 1e-3


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_time_invariance(seed):
    """Shifting the input shifts the output: y(shift(u)) == shift(y(u))."""
    ssm = init_modal(jax.random.PRNGKey(seed), (1,), 4, r_minmax=(0.3, 0.9))
    T = 48
    u = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, T))

    def outputs(u):
        xr = jnp.zeros((1, 4))
        xi = jnp.zeros((1, 4))
        ys = []
        for t in range(u.shape[-1]):
            y, xr, xi = modal_step(ssm, xr, xi, u[:, t])
            ys.append(y)
        return jnp.stack(ys, -1)

    y = outputs(u)
    u_shift = jnp.concatenate([jnp.zeros((1, 5)), u], axis=-1)
    y_shift = outputs(u_shift)
    np.testing.assert_allclose(np.asarray(y_shift[:, 5:]), np.asarray(y),
                               atol=1e-4)


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8), st.integers(1, 6),
       st.integers(16, 128))
@settings(max_examples=50, deadline=None)
def test_truncation_certificate_is_sound(seed, d, keep, L):
    """The static per-position truncation certificate upper-bounds the
    measured |full - truncated| filter error for arbitrary stable
    pole/residue sets (refit=False: poles and kept residues untouched, so
    the discarded-mode geometric series is an exact bound up to float32
    evaluation noise). The summed curve also stays under the closed-form
    h-l1 bound used by the serving drift gate."""
    from repro.core.truncation import (modal_truncation,
                                       truncation_error_certificate)
    keep = min(keep, d)
    ssm = init_modal(jax.random.PRNGKey(seed), (1,), d,
                     r_minmax=(0.2, 0.97))
    cert = truncation_error_certificate(ssm, keep, L)
    full = np.asarray(eval_filter(ssm, L), np.float64)[0]
    trunc = np.asarray(eval_filter(modal_truncation(ssm, keep), L),
                       np.float64)[0]
    err = np.abs(full - trunc)
    curve = np.asarray(cert["curve"], np.float64)[0]
    assert curve.shape == (L,) and curve[0] == 0.0
    scale = np.abs(full).max() + 1.0
    assert np.all(err <= curve + 1e-4 * scale), (err - curve).max()
    assert err[1:].sum() <= float(cert["l1_bound"][0]) + 1e-3 * scale
