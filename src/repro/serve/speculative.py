"""Self-speculative decoding on the distilled recurrence (paper Sec. 3 + 5.4).

Distillation gives a *spectrum* of fidelities per filter: a low-order modal
SSM is a cheap approximation of the same pretrained convolution that the
higher-order serving SSM (or the exact Lemma-2.1 cached-conv decode) computes
faithfully. That is precisely the draft/verify pair speculative decoding
needs, with zero extra training:

  draft  — `make_draft_params` modal-truncates every Hyena layer's serving
           SSM to `draft_order` (E.3.1 influence ranking, residues refit
           against the full-order distilled filter). The draft shares every
           other weight with the target.
  verify — all K drafted tokens (plus the pending last token) run through
           ONE multi-token `decode_chunk` of the full-fidelity model, which
           returns logits at every position. Greedy slots accept the longest
           draft prefix matching the target argmax; sampled slots run
           standard rejection sampling against the *filtered* target/draft
           distributions (same `filter_logits` the per-slot sampler uses),
           so the emitted distribution equals non-speculative sampling.
  commit — rollback protocol: `snapshot_cache_slots` before the verify
           advance; after acceptance the cache is restored and the accepted
           prefix replayed with per-row `active_len` (skipped entirely via
           lax.cond when every slot accepted in full). The draft slot pool
           is advanced by the same accepted prefix from its own committed
           state (the drafting scan runs on a functional copy).

Key tree (documented in serve/README.md): every slot carries a request key
fold_in(engine_key, rid); the token at per-slot stream index t derives
fold_in(request_key, t), then a purpose tag — DRAW_TAG for direct draws from
a model distribution (non-spec ticks, draft proposals, bonus tokens),
ACCEPT_TAG for the accept/reject uniform, RESIDUAL_TAG for the residual
draw on a rejection. Spec and non-spec paths therefore consume identical
key streams per emitted-token position.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HYENA, LOCAL_ATTN, ModelConfig
from repro.core.modal import ModalSSM, eval_filter
from repro.core.truncation import modal_truncation
from repro.models.layers import NOCTX, ShardCtx
from repro.models.model import (decode_chunk, decode_step, layer_layout,
                                restore_cache_slots, snapshot_cache_slots)
from repro.serve.sampling import filter_logits, sample_token_slots

# PRNG key-tree purpose tags (see module docstring / serve/README.md)
DRAW_TAG = 1
ACCEPT_TAG = 2
RESIDUAL_TAG = 3


def token_keys(slot_keys, tok_idx, tag: int):
    """Per-(slot, stream-index) keys: fold_in(slot_key, t) then the purpose
    tag. slot_keys (B, 2) uint32; tok_idx (B,) int32. Returns (B, 2)."""
    def one(k, t):
        return jax.random.fold_in(jax.random.fold_in(k, t), tag)
    return jax.vmap(one)(slot_keys, jnp.asarray(tok_idx, jnp.int32))


def _grid_keys(slot_keys, t_grid, tag: int):
    """Keys for a (B, K) grid of stream indices. Returns (B, K, 2)."""
    def one(k, t):
        return jax.random.fold_in(jax.random.fold_in(k, t), tag)
    return jax.vmap(jax.vmap(one, in_axes=(None, 0)))(slot_keys, t_grid)


# ---------------------------------------------------------------------------
# Draft model: modal truncation of the serving SSM
# ---------------------------------------------------------------------------
def make_draft_params(params, cfg: ModelConfig, draft_order: int, *,
                      refit: bool = True, fit_len: int = 1024,
                      embed: bool = False) -> Tuple[Any, ModelConfig]:
    """Build the low-order draft: every Hyena layer's distilled modal SSM is
    truncated to `draft_order` real states (E.3.1 h-inf influence ranking);
    with refit=True the kept residues are re-solved against the FULL-ORDER
    distilled filter materialized at fit_len, so the draft tracks the
    verifier as closely as the reduced order allows. All other weights are
    shared. Non-LCSM archs (or draft_order >= distill_order) return
    (params, cfg) unchanged — self-speculation against an identical model
    still works, with ~full acceptance.

    embed=False returns compact order-draft_order params (own state shapes —
    the separate-draft-pool layout the cached-conv serving mode uses).
    embed=True exploits that modal truncation keeps a SUBSET of modes with
    their poles untouched: the truncated system's state is exactly a
    sub-vector of the serving state, so the kept (refit) residues are
    scattered back into full-order arrays with zeros on dropped modes. The
    resulting draft reads the SERVING cache directly — no second slot pool,
    no draft prefill, no draft-state advance (draft_cfg == cfg)."""
    if cfg.hyena is None or draft_order >= cfg.hyena.distill_order:
        return params, cfg
    d2 = max(draft_order // 2, 1)
    draft_cfg = cfg if embed else cfg.replace(
        hyena=dataclasses.replace(cfg.hyena, distill_order=2 * d2))

    def trunc(dp):
        ssm = ModalSSM(dp["log_a"], dp["theta"], dp["R_re"], dp["R_im"],
                       dp["h0"])
        h = eval_filter(ssm, fit_len) if refit else None
        out, idx = modal_truncation(ssm, d2, refit=refit, h=h,
                                    return_indices=True)
        if not embed:
            return {"log_a": out.log_a, "theta": out.theta, "R_re": out.R_re,
                    "R_im": out.R_im, "h0": out.h0}
        put = lambda vals: jnp.put_along_axis(
            jnp.zeros_like(dp["R_re"]), idx, vals, axis=-1, inplace=False)
        return {"log_a": dp["log_a"], "theta": dp["theta"],
                "R_re": put(out.R_re), "R_im": put(out.R_im), "h0": out.h0}

    new = jax.tree.map(lambda x: x, params)       # fresh containers
    n_groups, n_rem = layer_layout(cfg)
    for i, kind in enumerate(cfg.pattern):
        if kind == HYENA:
            new["groups"][f"l{i}"]["mix"]["distilled"] = trunc(
                params["groups"][f"l{i}"]["mix"]["distilled"])
    for i in range(n_rem):
        if cfg.blocks[n_groups * len(cfg.pattern) + i] == HYENA:
            new["rem"][i]["mix"]["distilled"] = trunc(
                params["rem"][i]["mix"]["distilled"])
    return new, draft_cfg


# ---------------------------------------------------------------------------
# Draft phase: K single-token steps fused into one executable
# ---------------------------------------------------------------------------
def draft_tokens(draft_params, draft_cache, last, K: int, cfg: ModelConfig, *,
                 temperature, top_k, top_p, slot_keys, tok_idx,
                 ctx: ShardCtx = NOCTX):
    """Draft K tokens per slot with the low-order model: a lax.scan of
    `decode_step` feeding each slot's own samples back in. Proposals for
    stream index t are drawn with the DRAW_TAG key of t — the same key the
    non-speculative path would use for that position. The advanced draft
    cache is DISCARDED: the persistent draft pool stays at the committed
    position and is advanced by the accepted prefix in the verify step.
    Returns (tokens (B, K), draft_logits (B, K, V))."""
    def body(carry, j):
        cache, tok = carry
        cache, logits = decode_step(draft_params, cache, tok[:, None], cfg,
                                    ctx=ctx)
        lg = logits[:, 0, :]
        keys = token_keys(slot_keys, tok_idx + j, DRAW_TAG)
        nxt = sample_token_slots(keys, lg, temperature=temperature,
                                 top_k=top_k, top_p=top_p)
        return (cache, nxt), (nxt, lg)

    (_, _), (toks, lgs) = jax.lax.scan(body, (draft_cache, last),
                                       jnp.arange(K, dtype=jnp.int32))
    return jnp.moveaxis(toks, 0, 1), jnp.moveaxis(lgs, 0, 1)


# ---------------------------------------------------------------------------
# Acceptance: greedy prefix match / rejection sampling
# ---------------------------------------------------------------------------
def verify_tokens(target_logits, draft_logits, tokens, spec_len, *,
                  temperature, top_k, top_p, slot_keys, tok_idx):
    """Decide per-slot acceptance and the correction token.

    target_logits: (B, C, V) from the full-fidelity multi-token verify over
    tokens (B, C) = [last, d_1..d_K]; draft_logits: (B, K, V) (q_j is the
    draft distribution d_{j+1} was proposed from); spec_len (B,) in [1, C]
    caps how many positions row b actually speculates (1 = plain decode).

    Greedy rows (temperature <= 0) accept the longest prefix where the draft
    equals the target argmax; the correction is the target argmax at the
    first mismatch (or the bonus position). Sampled rows rejection-sample:
    accept d_{j+1} with prob min(1, p_j(d)/q_j(d)) over the FILTERED
    distributions, emit a residual draw from norm(max(p - q, 0)) on the
    first rejection, or a direct target draw for the bonus / non-spec rows.

    Returns (emitted (B, C) int32 — first n_emit entries valid per row,
    n_emit (B,) in [1, spec_len], n_acc (B,), correction (B,)).

    An all-greedy fast path (lax.cond) skips the filtered-distribution and
    rejection machinery entirely — the serving hot loop is usually greedy."""
    B, C, V = target_logits.shape
    K = C - 1
    assert K >= 1, "verify needs at least one drafted token"
    tok_idx = jnp.asarray(tok_idx, jnp.int32)
    spec_len = jnp.asarray(spec_len, jnp.int32)
    temperature = jnp.asarray(temperature, jnp.float32)
    greedy_row = temperature <= 0.0
    g = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)        # (B, C)
    drafts = tokens[:, 1:]                                          # (B, K)
    match_g = drafts == g[:, :K]

    def run_len(match):
        return jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)

    def greedy_branch(_):
        n_acc = jnp.minimum(run_len(match_g), spec_len - 1)
        g_r = jnp.take_along_axis(g, n_acc[:, None], axis=1)[:, 0]
        return n_acc, g_r

    def sampled_branch(_):
        flat = lambda x: x.reshape(B * K, V)
        rep = lambda p: jnp.repeat(p, K, axis=0)
        p_prob = jax.nn.softmax(filter_logits(
            flat(target_logits[:, :K]), temperature=rep(temperature),
            top_k=rep(top_k), top_p=rep(top_p)).reshape(B, K, V), axis=-1)
        q_prob = jax.nn.softmax(filter_logits(
            flat(draft_logits), temperature=rep(temperature),
            top_k=rep(top_k), top_p=rep(top_p)).reshape(B, K, V), axis=-1)
        p_d = jnp.take_along_axis(p_prob, drafts[..., None], -1)[..., 0]
        q_d = jnp.take_along_axis(q_prob, drafts[..., None], -1)[..., 0]
        t_grid = tok_idx[:, None] + jnp.arange(K, dtype=jnp.int32)[None, :]
        u = jax.vmap(jax.vmap(jax.random.uniform))(
            _grid_keys(slot_keys, t_grid, ACCEPT_TAG))
        accept_s = u * jnp.clip(q_d, 1e-30) <= p_d
        match = jnp.where(greedy_row[:, None], match_g, accept_s)
        n_acc = jnp.minimum(run_len(match), spec_len - 1)
        r = n_acc
        # correction token at position r (per row)
        corr_keys = token_keys(slot_keys, tok_idx + r, DRAW_TAG)
        res_keys = token_keys(slot_keys, tok_idx + r, RESIDUAL_TAG)
        p_r = filter_logits(
            jnp.take_along_axis(target_logits, r[:, None, None],
                                axis=1)[:, 0],
            temperature=temperature, top_k=top_k, top_p=top_p)      # (B, V)
        direct = jax.vmap(jax.random.categorical)(corr_keys,
                                                  p_r).astype(jnp.int32)
        # genuine rejection (not the spec_len cap, not the bonus slot)
        rejected = r < jnp.minimum(spec_len - 1, K)
        p_at_r = jnp.take_along_axis(
            p_prob, jnp.minimum(r, K - 1)[:, None, None], axis=1)[:, 0]
        q_at_r = jnp.take_along_axis(
            q_prob, jnp.minimum(r, K - 1)[:, None, None], axis=1)[:, 0]
        diff = jnp.maximum(p_at_r - q_at_r, 0.0)
        ok = jnp.sum(diff, axis=-1, keepdims=True) > 1e-12
        res_lg = jnp.where(ok & (diff > 0.0), jnp.log(jnp.clip(diff, 1e-30)),
                           -jnp.inf)
        # degenerate residual (p == q exactly): fall back to a direct draw
        res_lg = jnp.where(ok, res_lg, jnp.log(jnp.clip(p_at_r, 1e-30)))
        residual = jax.vmap(jax.random.categorical)(
            res_keys, res_lg).astype(jnp.int32)
        corr_sampled = jnp.where(rejected, residual, direct)
        g_r = jnp.take_along_axis(g, r[:, None], axis=1)[:, 0]
        return n_acc, jnp.where(greedy_row, g_r, corr_sampled)

    n_acc, correction = jax.lax.cond(jnp.all(greedy_row), greedy_branch,
                                     sampled_branch, None)

    jgrid = jnp.arange(C, dtype=jnp.int32)[None, :]
    drafts_pad = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((B, 1), jnp.int32)], axis=1)      # (B, C)
    emitted = jnp.where(jgrid < n_acc[:, None], drafts_pad,
                        jnp.where(jgrid == n_acc[:, None],
                                  correction[:, None], 0))
    return emitted, n_acc + 1, n_acc, correction


# ---------------------------------------------------------------------------
# Verify + commit: one fused executable per tick
# ---------------------------------------------------------------------------
def spec_verify_commit(params, draft_params, cache, last, draft_toks,
                       draft_logits, spec_len, draft_cache, cfg: ModelConfig,
                       draft_cfg: ModelConfig, *, temperature, top_k, top_p,
                       slot_keys, tok_idx, ctx: ShardCtx = NOCTX,
                       conv_filters=None, select_commit: bool = False):
    """One speculative round against the slot pools (see module docstring).

    Rollback protocol, two implementations:
      * select_commit=True (pure distilled-Hyena archs): the verify
        decode_chunk collects per-position states and the committed cache is
        SELECTED at each row's accepted length (`commit_cache_from_states`)
        — one forward pass total.
      * generic: snapshot -> decode_chunk over C = K+1 tokens with per-row
        active_len = spec_len (logits at every position) -> acceptance ->
        restore + replay with active_len = n_emit (logits skipped). The
        replay is skipped entirely via lax.cond when every slot accepted in
        full (the verify advance already IS the committed state then).

    `draft_cache` is None for the state-sharing draft (embed=True draft
    params read the serving cache — nothing to advance); for the
    separate-pool draft (cached-conv mode) it is still at the committed
    position — the drafting scan ran on a copy — and is advanced here by
    the same accepted prefix.

    Returns (cache, draft_cache_or_None, emitted (B, C), n_emit (B,),
    new_last (B,), new_tok_idx (B,))."""
    B, K = draft_toks.shape
    tokens = jnp.concatenate([last[:, None], draft_toks], axis=1)   # (B, C)
    if select_commit:
        from repro.models.model import commit_cache_from_states
        _, logits, aux = decode_chunk(params, cache, tokens, cfg,
                                      active_len=spec_len, ctx=ctx,
                                      conv_filters=conv_filters,
                                      collect_states=True)
        emitted, n_emit, n_acc, correction = verify_tokens(
            logits, draft_logits, tokens, spec_len, temperature=temperature,
            top_k=top_k, top_p=top_p, slot_keys=slot_keys, tok_idx=tok_idx)
        new_cache = commit_cache_from_states(aux, n_emit, cfg)
    else:
        snap = snapshot_cache_slots(cache, cfg, K + 1)
        cache1, logits = decode_chunk(params, cache, tokens, cfg,
                                      active_len=spec_len, ctx=ctx,
                                      conv_filters=conv_filters)
        emitted, n_emit, n_acc, correction = verify_tokens(
            logits, draft_logits, tokens, spec_len, temperature=temperature,
            top_k=top_k, top_p=top_p, slot_keys=slot_keys, tok_idx=tok_idx)

        def keep(args):
            cache1, _ = args
            return cache1

        def roll(args):
            cache1, snap = args
            rb = restore_cache_slots(cache1, snap, cfg)
            c2, _ = decode_chunk(params, rb, tokens, cfg, active_len=n_emit,
                                 ctx=ctx, conv_filters=conv_filters,
                                 need_logits=False)
            return c2

        new_cache = jax.lax.cond(jnp.all(n_emit == spec_len), keep, roll,
                                 (cache1, snap))
    new_draft_cache = None
    if draft_cache is not None:
        new_draft_cache, _ = decode_chunk(draft_params, draft_cache, tokens,
                                          draft_cfg, active_len=n_emit,
                                          ctx=ctx, need_logits=False)
    return (new_cache, new_draft_cache, emitted, n_emit, correction,
            tok_idx + n_emit)


def spec_round(params, draft_params, cache, last, spec_len, draft_cache,
               K: int, cfg: ModelConfig, draft_cfg: ModelConfig, *,
               temperature, top_k, top_p, slot_keys, tok_idx,
               ctx: ShardCtx = NOCTX, conv_filters=None,
               select_commit: bool = False):
    """One full speculative round — draft scan + verify/commit — fused into
    a single executable so the serving loop pays ONE dispatch per up to
    K + 1 tokens per slot. The draft scan reads the serving cache itself
    when draft_cache is None (state-sharing draft), else the separate draft
    pool; either way its advanced state is discarded and only the accepted
    prefix is committed."""
    draft_src = cache if draft_cache is None else draft_cache
    draft_toks, draft_logits = draft_tokens(
        draft_params, draft_src, last, K, draft_cfg, temperature=temperature,
        top_k=top_k, top_p=top_p, slot_keys=slot_keys, tok_idx=tok_idx,
        ctx=ctx)
    return spec_verify_commit(
        params, draft_params, cache, last, draft_toks, draft_logits,
        spec_len, draft_cache, cfg, draft_cfg, temperature=temperature,
        top_k=top_k, top_p=top_p, slot_keys=slot_keys, tok_idx=tok_idx,
        ctx=ctx, conv_filters=conv_filters, select_commit=select_commit)


# ---------------------------------------------------------------------------
# Jitted entry points (shared memo with the other serving executables)
# ---------------------------------------------------------------------------
def jitted_spec_round(cfg: ModelConfig, draft_cfg: ModelConfig, K: int,
                      shared_draft: bool, ctx: ShardCtx = NOCTX):
    """Positional args: (params, draft_params, cache, last, spec_len,
    draft_cache) — pass draft_cache=None with shared_draft=True. The
    serving cache (and the draft pool, when separate) is donated. The
    selection-commit is enabled automatically for archs that support it."""
    from repro.models.model import supports_state_select
    from repro.serve.engine import _JIT_CACHE
    sel = shared_draft and supports_state_select(cfg)
    key = ("spec_round", cfg, draft_cfg, K, shared_draft, id(ctx))
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = jax.jit(
            functools.partial(spec_round, K=K, cfg=cfg, draft_cfg=draft_cfg,
                              ctx=ctx, select_commit=sel),
            donate_argnums=(2,) if shared_draft else (2, 5))
    return _JIT_CACHE[key]


def validate_spec_config(cfg: ModelConfig, spec_k: int) -> None:
    """Speculation horizon constraints: ring buffers must hold a whole
    verify window (snapshot regions would alias otherwise)."""
    if spec_k < 1:
        raise ValueError(f"spec_k must be >= 1, got {spec_k}")
    if any(b == LOCAL_ATTN for b in cfg.blocks) and cfg.window > 0 \
            and cfg.window < spec_k + 1:
        raise ValueError(
            f"spec_k={spec_k} needs window >= {spec_k + 1} for the ring "
            f"snapshot (got window={cfg.window})")
    if cfg.enc_dec or cfg.frontend != "none":
        raise ValueError("speculative decoding does not support "
                         "enc-dec/frontend architectures")
