"""Mamba2-130M [arXiv:2405.21060].

SSM (attention-free): 24L d_model=768, SSD with d_state=128, expand=2,
head_dim=64, vocab=50280. Sub-quadratic: runs long_500k.
"""
from repro.configs.base import MAMBA2, ModelConfig, SSMConfig, register


@register
def mamba2_130m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=24,        # SSD heads = expand*d_model/head_dim = 24
        n_kv_heads=1,
        head_dim=64,
        d_ff=0,            # attention-free, no separate MLP (Mamba block only)
        vocab=50280,
        act="swiglu",
        norm="rmsnorm",
        pattern=(MAMBA2,),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        tie_embeddings=True,
        max_seq=1_048_576,
    )
