"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import to
materialize the placeholder devices.

Topology (TPU v5e-256 pods):
  single pod : (data=16, model=16)            = 256 chips
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over actually-present devices (tests / smoke runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


HW = {
    # TPU v5e per-chip constants used for the roofline terms
    "peak_flops_bf16": 197e12,      # FLOP/s
    "hbm_bw": 819e9,                # B/s
    "ici_bw": 50e9,                 # B/s per link
    "hbm_bytes": 16e9,
}
