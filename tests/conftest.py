import os
import sys

# tests see the real (single) CPU device — the 512-device flag is ONLY for
# the dry-run (repro/launch/dryrun.py sets it before importing jax).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
