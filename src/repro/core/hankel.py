"""Hankel spectrum analysis (paper Sec. 3.3).

The McMillan degree of a filter equals the rank of its (infinite) Hankel
operator (Ho-Kalman, Thm. 3.1); the decay of the singular values of the
L x L principal sub-matrix S_L predicts the achievable distillation error at
a given order (AAK, Thm. 3.2: inf_{rank d} ||S_L - S_hat||_2 = sigma_{d+1}).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def hankel_matrix(h: jnp.ndarray) -> jnp.ndarray:
    """S_L from a filter h (..., L): S[i, j] = h[i + j + 1] (Markov params).

    Index 0 of h is the passthrough term and does not enter the Hankel
    operator. Output: (..., m, m) with m = (L - 1 + 1) // 2 so every entry is
    defined from available samples.
    """
    L = h.shape[-1]
    m = L // 2
    i = np.arange(m)[:, None] + np.arange(m)[None, :] + 1
    return h[..., i]


def hankel_singular_values(h: jnp.ndarray) -> jnp.ndarray:
    """Singular values of S_L, descending. h: (..., L) -> (..., m)."""
    S = hankel_matrix(h).astype(jnp.float32)
    return jnp.linalg.svd(S, compute_uv=False)


def suggest_order(sv: jnp.ndarray, tol: float = 1e-3) -> jnp.ndarray:
    """Smallest d with sigma_{d+1} / sigma_1 < tol (rule of thumb, Sec. 3.3)."""
    rel = sv / jnp.clip(sv[..., :1], 1e-30)
    return jnp.sum(rel >= tol, axis=-1)


def aak_lower_bound(sv: jnp.ndarray, d: int) -> jnp.ndarray:
    """AAK: no order-d system gets Hankel error below sigma_{d+1} (Thm. 3.2)."""
    return sv[..., d]
