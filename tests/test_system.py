"""End-to-end system test of the paper's pipeline:

  pretrain a small MultiHyena -> Hankel analysis -> LaughingHyena distill ->
  recurrent decode matches the convolutional forward (Sec. 5.2's logit-error
  criterion) -> beats the random-SSM baseline by a wide margin.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow    # full train->distill->serve pipeline (~40s)

from repro.configs import get_config, smoke_config
from repro.core.distill import distill_model
from repro.data.pipeline import SyntheticLM, make_batches
from repro.distributed.sharding import unzip
from repro.models.model import decode_step, forward, init_params, prefill
from repro.train.train_step import init_opt, make_train_step


@pytest.fixture(scope="module")
def trained():
    cfg = smoke_config(get_config("multihyena-153m")).replace(
        dtype="float32", vocab=128)
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    opt = init_opt(params)
    src = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=5)
    step = jax.jit(make_train_step(cfg, None, base_lr=2e-3, warmup=10,
                                   total_steps=150, remat="none"))
    losses = []
    for i in range(150):
        params, opt, m = step(params, opt, {"tokens": jnp.asarray(src.batch(i))},
                              jnp.asarray(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, "pretraining must make progress"
    return cfg, params


def _decode_errs(cfg, params, toks, P):
    full, _ = forward(params, toks, cfg)
    cache, last = prefill(params, toks[:, :P], cfg, max_len=toks.shape[1])
    errs = [float(jnp.max(jnp.abs(last - full[:, P - 1])))]
    for t in range(P, toks.shape[1]):
        cache, lg = decode_step(params, cache, toks[:, t:t + 1], cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    scale = float(jnp.max(jnp.abs(full)))
    return max(errs) / scale


def test_distilled_decode_matches_forward(trained):
    cfg, params = trained
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 48), 0, cfg.vocab)
    before = _decode_errs(cfg, params, toks, 40)           # random SSM slot
    distilled, errs = distill_model(params, cfg, steps=2500, L=256)
    for k, e in errs.items():
        assert not bool(jnp.isnan(e).any())
    after = _decode_errs(cfg, distilled, toks, 40)
    # paper criterion: relative logit error small (Fig 5.1: <1e-2 at the
    # 99.99th percentile; we bound the max over all logits at reduced
    # training), and no worse than the undistilled random-SSM slot
    assert after < 0.1, after
    assert after <= before, (before, after)


def test_hankel_spectrum_predicts_trained_compressibility(trained):
    """After training, filters admit low-order SSMs (Sec. 4 observation):
    the Hankel spectrum decays and predicts distillability."""
    from repro.core.hankel import hankel_singular_values, suggest_order
    from repro.models.hyena import materialize_filters
    cfg, params = trained
    fp = jax.tree.map(lambda x: x[0], params["groups"]["l0"]["mix"]["filter"])
    h, _ = materialize_filters(fp, 256, cfg.hyena)
    sv = hankel_singular_values(h)
    orders = suggest_order(sv, tol=1e-2)
    assert int(jnp.max(orders)) <= 64, orders


def test_generation_engine_after_distillation(trained):
    from repro.serve.engine import GenerationEngine
    cfg, params = trained
    distilled, _ = distill_model(params, cfg, steps=800, L=256)
    eng = GenerationEngine(distilled, cfg, max_len=96)
    toks, info = eng.generate(jax.random.PRNGKey(0),
                              jnp.ones((2, 16), jnp.int32), 8,
                              temperature=0.0)
    assert toks.shape == (2, 8)
    # constant-memory decode: state bytes independent of generated length
    assert info["cache_bytes"] < 5e6
