"""Serving launcher: batched auto-regressive generation and the
continuous-batching request-stream mode.

Fixed-batch generation (original behavior):

  PYTHONPATH=src python -m repro.launch.serve --arch multihyena-153m --smoke \
      --batch 8 --prompt-len 64 --gen 32 [--ckpt /tmp/run1] [--distill]

Request-stream serving (Poisson arrivals, mixed prompt lengths, slot-pool
continuous batching; reports tokens/s and p50/p99 latency):

  PYTHONPATH=src python -m repro.launch.serve --arch multihyena-153m --smoke \
      --distill --stream --n-requests 16 --rate 20 --slots 4 \
      --mode distilled            # or cached_conv / epoch (exact FFT path)

The distilled path can be guarded by the online drift sentinel
(--drift-check-every N [--drift-tol T]): every N ticks one resident slot's
next token is re-derived through the exact epoched-FFT path and compared;
divergence beyond the tolerance demotes the engine to the epoch mode.

Serving fast path (all on by default in --stream mode): prompt-length
bucketing (one batched prefill executable per power-of-two bucket), the
async overlapped tick loop, and optional chunked prefill for long prompts
(--chunk N). --no-bucket / --sync-loop restore the legacy per-length,
fully-synchronous engine for comparison.

For LCSM archs, --distill runs LaughingHyena distillation before serving
(recurrent O(d) decode); without it the model still serves via the distilled
slot's random init (useless outputs) — so in practice always pass --distill
or a --ckpt of a trained+distilled model.

Observability (serve/README.md "Observability"): --metrics-port N serves the
engine's live metrics registry over HTTP while the stream runs (/metrics
Prometheus text, /metrics.json snapshot, /trace.json live trace);
--trace-out FILE records host-phase + request-lifecycle spans and writes a
Chrome-trace JSON to open in Perfetto; --events-limit bounds the recovery-
event ring.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.distill import distill_model
from repro.distributed.sharding import unzip
from repro.models.model import init_params
from repro.serve.engine import GenerationEngine
from repro.serve.scheduler import (ContinuousBatchingEngine, SamplingParams,
                                   run_request_stream,
                                   synthesize_request_stream)
from repro.train.checkpoint import Checkpointer


def _spec_k_arg(v: str):
    return v if v == "auto" else int(v)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--distill", action="store_true")
    ap.add_argument("--distill-order", type=int, default=None,
                    help="default: cfg.hyena.distill_order (the order the "
                         "decode cache is sized for)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=("distilled", "cached_conv", "epoch"),
                    default="distilled")
    # request-stream serving
    ap.add_argument("--stream", action="store_true",
                    help="continuous-batching request-stream mode")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-lens", type=str, default=None,
                    help="comma list of prompt lengths (default: "
                         "prompt-len/2,prompt-len)")
    # serving fast path
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable prompt-length bucketing (compile one "
                         "prefill executable per distinct length)")
    ap.add_argument("--chunk", type=int, default=None,
                    help="chunked prefill: prompts longer than this run "
                         "through the resumable chunk executable, one chunk "
                         "per tick")
    ap.add_argument("--sync-loop", action="store_true",
                    help="disable the async overlapped host loop")
    ap.add_argument("--prefills-per-step", type=int, default=2,
                    help="max admissions per tick == bucketed prefill batch")
    # self-speculative decoding (serve/speculative.py)
    ap.add_argument("--spec-k", type=_spec_k_arg, default=0,
                    help="speculative decoding: draft this many tokens per "
                         "slot per tick with the low-order modal truncation "
                         "of the serving SSM and verify them in one "
                         "multi-token step (0 disables). 'auto' runs the "
                         "construction-time autotune sweep and adopts the "
                         "measured winner (or disables speculation)")
    ap.add_argument("--draft-order", type=int, default=None,
                    help="real state dim of the draft's modal truncation "
                         "(default: half the serving distill order)")
    ap.add_argument("--spec-branch", type=int, default=1,
                    help="top-k tree drafts: draft this many chains per "
                         "slot (branching once at depth 0) and verify them "
                         "all in one call (1 = single chain)")
    # resilience (serve/README.md "Failure handling")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request end-to-end deadline; expired requests "
                         "finish with ERROR status instead of queueing "
                         "forever")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded-queue admission control: submissions past "
                         "this queue depth are rejected with ERROR status")
    ap.add_argument("--fault-schedule", type=str, default=None,
                    help="JSON fault schedule (file path or inline) driving "
                         "a seeded serve/faults.FaultInjector: corrupt slot "
                         "state, raise in dispatch, stall the loop, expire "
                         "deadlines")
    ap.add_argument("--drift-check-every", type=int, default=0,
                    help="distillation-drift sentinel: every N ticks, "
                         "re-decode one resident slot's next token through "
                         "the exact epoched-FFT path and record the "
                         "log-softmax divergence vs the distilled engine "
                         "(0 disables; distilled mode only)")
    ap.add_argument("--drift-tol", type=float, default=None,
                    help="sentinel alarm threshold: divergence above this "
                         "demotes the engine to the exact epoch path")
    ap.add_argument("--restore", type=str, default=None,
                    help="resume from an engine checkpoint written by "
                         "serve.checkpoint.save_engine (bit-exact for "
                         "resident slots)")
    # observability (serve/README.md "Observability")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the engine's metrics registry over HTTP on "
                         "this port while the stream runs (/metrics "
                         "Prometheus text, /metrics.json snapshot, "
                         "/trace.json live Chrome trace; 0 picks a free "
                         "port)")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="record request-lifecycle + host-phase spans and "
                         "write a Chrome-trace JSON here at the end (open "
                         "in https://ui.perfetto.dev)")
    ap.add_argument("--events-limit", type=int, default=256,
                    help="ring-buffer capacity of the recovery-event log "
                         "(0 = unbounded)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = unzip(init_params(key, cfg))
    if args.ckpt:
        ck = Checkpointer(args.ckpt)
        (params, _), step = ck.restore((params, None))
        print(f"[serve] restored step {step}")
    if args.distill and cfg.hyena is not None:
        t0 = time.time()
        order = args.distill_order or cfg.hyena.distill_order
        params, errs = distill_model(params, cfg, d=order)
        worst = max(float(jnp.max(e)) for e in errs.values())
        print(f"[serve] distilled filters to order {order} in "
              f"{time.time()-t0:.1f}s (worst rel l2 err {worst:.3e})")

    if args.stream:
        _serve_stream(params, cfg, args)
        return

    engine = GenerationEngine(params, cfg,
                              max_len=args.prompt_len + args.gen,
                              mode=args.mode)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    toks, info = engine.generate(key, prompt, args.gen,
                                 temperature=args.temperature,
                                 top_k=args.top_k, top_p=args.top_p)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s), cache={info['cache_bytes']/1e6:.2f}MB")
    print(toks[0][:16])


def _serve_stream(params, cfg, args):
    if args.prompt_lens:
        plens = tuple(int(x) for x in args.prompt_lens.split(","))
    else:
        plens = (max(args.prompt_len // 2, 4), args.prompt_len)
    max_len = max(plens) + args.gen
    injector = None
    if args.fault_schedule:
        from repro.serve.faults import FaultInjector
        injector = FaultInjector.from_json(args.fault_schedule)
        print(f"[serve] fault schedule: {len(injector.events)} events "
              f"(seed {injector.seed})")
    tracer = None
    if args.trace_out:
        from repro.serve.trace import Tracer
        tracer = Tracer()
    eng = ContinuousBatchingEngine(params, cfg, n_slots=args.slots,
                                   max_len=max_len, mode=args.mode,
                                   seed=args.seed,
                                   bucket_prompts=not args.no_bucket,
                                   prefill_chunk=args.chunk,
                                   overlap=not args.sync_loop,
                                   max_prefills_per_step=args.prefills_per_step,
                                   spec_k=args.spec_k,
                                   draft_order=args.draft_order,
                                   spec_branch=args.spec_branch,
                                   deadline_s=(args.deadline_ms / 1e3
                                               if args.deadline_ms else None),
                                   max_queue=args.max_queue,
                                   fault_injector=injector,
                                   tracer=tracer,
                                   events_limit=args.events_limit or None,
                                   drift_check_every=args.drift_check_every,
                                   drift_tol=args.drift_tol)
    server = None
    if args.metrics_port is not None:
        from repro.serve.metrics import start_metrics_server
        server = start_metrics_server(
            eng.metrics, args.metrics_port, tracer=eng.tracer,
            extra=lambda: {"stats": dict(eng.stats),
                           "resilience": eng.resilience.snapshot(),
                           "tick": eng._tick})
        print(f"[serve] metrics endpoint: "
              f"http://{server.server_address[0]}:{server.server_address[1]}"
              f"/metrics (also /metrics.json, /trace.json)")
    if args.restore:
        from repro.serve.checkpoint import restore_engine
        restore_engine(eng, args.restore)
        print(f"[serve] restored engine checkpoint {args.restore} "
              f"(tick {eng._tick}, {eng.n_active} resident slots, "
              f"{len(eng.queue)} queued)")
    if eng.spec_report is not None:
        print(f"[serve] autotune sweep (spec_k=auto):\n"
              f"{eng.spec_report.pretty()}")
    spec_desc = (f", spec_k={eng._spec_k}" if eng._spec else "")
    print(f"[serve] warming up prompt lengths {plens} "
          f"({'bucketed' if not args.no_bucket else 'exact-length'} prefill"
          f"{', chunk=%d' % args.chunk if args.chunk else ''}, "
          f"{'overlapped' if not args.sync_loop else 'sync'} loop"
          f"{spec_desc}) ...")
    eng.warmup(plens)
    sampling = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                              top_p=args.top_p)
    stream = synthesize_request_stream(
        np.random.default_rng(args.seed), args.n_requests, rate=args.rate,
        prompt_lens=plens, gen_tokens=(max(args.gen // 2, 1), args.gen),
        vocab=cfg.vocab, sampling=sampling)
    m = run_request_stream(eng, stream)
    print(f"[serve] mode={args.mode} slots={args.slots} "
          f"{int(m['n_requests'])} requests / {int(m['n_tokens'])} tokens "
          f"in {m['wall_s']:.2f}s")
    print(f"[serve] tok/s={m['tok_per_s']:.1f} "
          f"decode_tok/s={m['decode_tok_per_s']:.1f}  "
          f"latency p50={m['p50_latency_s']*1e3:.1f}ms "
          f"p99={m['p99_latency_s']*1e3:.1f}ms  "
          f"ttft p50={m['p50_ttft_s']*1e3:.1f}ms "
          f"p99={m['p99_ttft_s']*1e3:.1f}ms")
    if eng._spec:
        from repro.serve.metrics import speculative_summary
        s = speculative_summary(eng.stats)
        acc = s["acceptance_rate"]
        tpr = s["tokens_per_slot_round"]
        print(f"[serve] speculative: "
              f"acceptance={acc if acc is not None else float('nan'):.2f} "
              f"tokens/slot-round="
              f"{tpr if tpr is not None else float('nan'):.2f} "
              f"(draft order {eng.draft_order}, K={eng._spec_k}, "
              f"branch={eng._spec_branch})")
    if eng.resilience.get("drift_checks"):
        h = eng.metrics.get("serve_drift_logit_div")
        print(f"[serve] drift sentinel: "
              f"{eng.resilience.get('drift_checks')} checks, "
              f"{eng.resilience.get('drift_alarms')} alarms, "
              f"last divergence "
              f"{eng._drift_last if eng._drift_last is not None else float('nan'):.3e} "
              f"(max {h._max:.3e}, tol "
              f"{args.drift_tol if args.drift_tol is not None else 'off'}), "
              f"final mode {eng.mode}")
    print(f"[serve] scheduler stats: {eng.stats}")
    print(f"[serve] prefill compile stats: {eng.prefill_compile_stats()}")
    res = {k: v for k, v in m["resilience"].items() if v}
    if res or m["n_errors"]:
        print(f"[serve] resilience: {m['n_errors']} error completions, "
              f"counters {res}")
    if eng.events:
        dropped = eng._events_total - len(eng.events)
        print(f"[serve] recovery events ({len(eng.events)} of "
              f"{eng._events_total} retained):" if dropped
              else f"[serve] recovery events ({len(eng.events)}):")
        for ev in eng.events:
            detail = {k: v for k, v in ev.items()
                      if k not in ("tick", "kind")}
            print(f"  tick {ev['tick']:>5}  {ev['kind']:<16} {detail}")
    if tracer is not None:
        tracer.save(args.trace_out)
        print(f"[serve] wrote trace ({len(tracer)} events, "
              f"{tracer.dropped} dropped) to {args.trace_out} — open in "
              f"https://ui.perfetto.dev")
    if server is not None:
        server.shutdown()


if __name__ == "__main__":
    main()
