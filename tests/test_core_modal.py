"""Core modal-form machinery: Lemma 3.1 evaluation, Prop 3.3 recurrence,
Hankel analysis (Thm 3.1/3.2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (aak_lower_bound, eval_filter, hankel_matrix,
                        hankel_singular_values, init_modal, modal_step,
                        suggest_order)
from repro.core.distill import distill_filters
from repro.core.hankel import hankel_matrix


def test_eval_filter_matches_recurrence():
    """The O(dL) filter evaluation equals unrolling the recurrent step on a
    unit impulse (definition of impulse response)."""
    ssm = init_modal(jax.random.PRNGKey(0), (4,), 6, r_minmax=(0.4, 0.9))
    L = 64
    h = eval_filter(ssm, L)
    xr = jnp.zeros((4, 6))
    xi = jnp.zeros((4, 6))
    out = []
    for t in range(L):
        u = jnp.full((4,), 1.0 if t == 0 else 0.0)
        y, xr, xi = modal_step(ssm, xr, xi, u)
        out.append(y)
    imp = jnp.stack(out, -1)
    np.testing.assert_allclose(np.asarray(imp), np.asarray(h), atol=1e-4)


def test_hankel_rank_of_exact_system():
    """A rank-d' system's Hankel matrix has numerical rank <= 2*modes
    (conjugate completion) — Thm 3.1."""
    ssm = init_modal(jax.random.PRNGKey(1), (1,), 4, r_minmax=(0.3, 0.8))
    h = eval_filter(ssm, 256)
    sv = hankel_singular_values(h)[0]
    rel = sv / sv[0]
    assert float(rel[8]) < 1e-4        # rank <= 8 = 2*4 modes
    assert int(suggest_order(sv[None], 1e-4)[0]) <= 8


def test_aak_bound_respected():
    """Achieved Hankel-norm error of an order-d approximant is >= sigma_{d+1}
    (d = 2*modes real order) — Thm 3.2 direction check."""
    ssm = init_modal(jax.random.PRNGKey(2), (1,), 8, r_minmax=(0.5, 0.9))
    h = eval_filter(ssm, 256)
    sv = hankel_singular_values(h)
    modes = 2
    fit, _ = distill_filters(h, modes, steps=600)
    res = hankel_matrix(eval_filter(fit, 256) - h)[0]
    achieved = float(jnp.linalg.norm(res.astype(jnp.float32), 2))
    bound = float(aak_lower_bound(sv, 2 * modes)[0])
    assert achieved >= bound * 0.98    # small numerical slack


def test_modal_step_linearity():
    ssm = init_modal(jax.random.PRNGKey(3), (2,), 4)
    xr = jax.random.normal(jax.random.PRNGKey(4), (2, 4))
    xi = jax.random.normal(jax.random.PRNGKey(5), (2, 4))
    u = jnp.ones((2,))
    y1, a1, b1 = modal_step(ssm, xr, xi, u)
    y2, a2, b2 = modal_step(ssm, 2 * xr, 2 * xi, 2 * u)
    np.testing.assert_allclose(np.asarray(2 * y1), np.asarray(y2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(2 * a1), np.asarray(a2), rtol=1e-5)
