"""Abstract input specs (ShapeDtypeStruct) + shardings for every dry-run cell.

No device allocation happens here: params/optimizer/caches are produced with
jax.eval_shape over the real init functions, so the dry-run lowers exactly
the structures the real launcher would build.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (Param, SERVE_RULES, TRAIN_RULES,
                                        ShardingRules, resolve_spec, unzip)
from repro.models.model import init_cache, init_params
from repro.optim.adamw import adamw_init


def _shardings_for(axes_tree, shapes_tree, rules: ShardingRules, mesh: Mesh):
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    vals_flat, treedef = jax.tree.flatten(shapes_tree)
    # axes leaves are tuples of strings; flatten against the value structure
    axes_flat = treedef.flatten_up_to(axes_tree)
    out = [NamedSharding(mesh, resolve_spec(tuple(v.shape), tuple(a),
                                            rules, mesh_shape))
           for v, a in zip(vals_flat, axes_flat)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules):
    """(ShapeDtypeStruct tree, NamedSharding tree) for model params.

    The Param wrapper carries static string axes, so we capture the axes tree
    as a tracing side effect and eval_shape only the value tree."""
    box = {}

    def build():
        values, axes = unzip(init_params(jax.random.PRNGKey(0), cfg))
        box["axes"] = axes
        return values

    values = jax.eval_shape(build)
    axes = box["axes"]
    shardings = _shardings_for(axes, values, rules, mesh)
    return values, axes, shardings


def abstract_opt(values, axes, mesh: Mesh, rules: ShardingRules):
    opt = jax.eval_shape(adamw_init, values)
    opt_axes = type(opt)(count=(), mu=axes, nu=axes)
    shardings = _shardings_for(opt_axes, opt, rules, mesh)
    return opt, opt_axes, shardings


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, mesh: Mesh,
                   rules: ShardingRules):
    box = {}

    def build():
        values, axes = unzip(init_cache(cfg, batch, max_len))
        box["axes"] = axes
        return values

    values = jax.eval_shape(build)
    axes = box["axes"]
    shardings = _shardings_for(axes, values, rules, mesh)
    return values, axes, shardings


def batch_spec(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               rules: ShardingRules) -> Tuple[Dict, Dict]:
    """Abstract train batch {tokens, [frontend]} + shardings."""
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    axes = {"tokens": ("batch", None)}
    if cfg.frontend != "none":
        batch["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        axes["frontend"] = ("batch", None, "act_embed")
    shardings = _shardings_for(axes, batch, rules, mesh)
    return batch, shardings


def decode_token_spec(shape: ShapeConfig, mesh: Mesh, rules: ShardingRules):
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    sh = _shardings_for(("batch", None), tok, rules, mesh)
    return tok, sh


def prompt_spec(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                rules: ShardingRules):
    B, S = shape.global_batch, shape.seq_len
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
    sh = _shardings_for(("batch", None), toks, rules, mesh)
    out = {"tokens": (toks, sh)}
    if cfg.frontend != "none":
        fe = jax.ShapeDtypeStruct((B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        out["frontend"] = (fe, _shardings_for(("batch", None, "act_embed"), fe,
                                              rules, mesh))
    return out
