"""Classical model-order-reduction baselines (paper App. E.3).

Balanced truncation via Kung's Hankel-SVD algorithm (E.3.2, following [24])
and modal truncation for diagonal SSMs (E.3.1). These are the baselines the
paper compares gradient-based modal interpolation against.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.distill import fit_residues
from repro.core.hankel import hankel_matrix
from repro.core.modal import ModalSSM


def balanced_truncation(h: jnp.ndarray, d: int):
    """E.3.2 steps 1-4: order-d balanced realization from impulse response.

    h: (L,) single filter. Returns dense (A (d,d), B (d,), C (d,), h0) —
    complex-free (real) balanced realization.
    """
    S = hankel_matrix(h).astype(jnp.float32)
    U, s, Vt = jnp.linalg.svd(S, full_matrices=False)
    sq = jnp.sqrt(s[:d] + 1e-30)
    O = U[:, :d] * sq[None, :]                 # observability factor
    Ct = Vt[:d, :] * sq[:, None]               # controllability factor
    A = jnp.linalg.pinv(O[:-1, :]) @ O[1:, :]
    B = Ct[:, 0]
    C = O[0, :]
    return A, B, C, h[0]


def balanced_truncation_modal(h: jnp.ndarray, d: int) -> ModalSSM:
    """Balanced truncation followed by diagonalization into modal form."""
    A, B, C, h0 = balanced_truncation(h, d)
    lam, V = jnp.linalg.eig(A)
    Bt = jnp.linalg.solve(V, B.astype(V.dtype))
    Ct = C.astype(V.dtype) @ V
    R = Bt * Ct
    return ModalSSM(
        log_a=jnp.log(jnp.clip(jnp.abs(lam), 1e-8)).astype(jnp.float32),
        theta=jnp.angle(lam).astype(jnp.float32),
        R_re=jnp.real(R).astype(jnp.float32),
        R_im=jnp.imag(R).astype(jnp.float32),
        h0=jnp.asarray(h0, jnp.float32),
    )


def modal_truncation(ssm: ModalSSM, n: int, refit: bool = False,
                     h: jnp.ndarray = None, return_indices: bool = False):
    """E.3.1: keep the n most influential modes of a diagonal SSM.

    Modes ranked by the h-inf bound |R_i| / |1 - |lam_i|| (Eq. E.2).
    With refit=True the kept residues are re-solved against h (linear LSQ).
    With return_indices=True also returns the kept-mode indices (..., n)
    into the original mode axis — the truncated system's state is exactly
    that sub-vector of the full system's state (poles are untouched), which
    is what lets a speculative draft share the serving cache.
    """
    a = jnp.exp(ssm.log_a)
    infl = jnp.abs(ssm.residues()) / jnp.clip(jnp.abs(1.0 - a), 1e-6)
    idx = jnp.argsort(-infl, axis=-1)[..., :n]
    take = lambda arr: jnp.take_along_axis(arr, idx, axis=-1)
    out = ModalSSM(take(ssm.log_a), take(ssm.theta), take(ssm.R_re),
                   take(ssm.R_im), ssm.h0)
    if refit and h is not None:
        R = fit_residues(out.poles(), h)
        out = out._replace(R_re=jnp.real(R).astype(jnp.float32),
                           R_im=jnp.imag(R).astype(jnp.float32))
    if return_indices:
        return out, idx
    return out


def truncation_error_certificate(ssm: ModalSSM, n: int, L: int):
    """Static per-position error certificate for `modal_truncation`
    (refit=False): with the kept modes' poles AND residues untouched, the
    filter gap is exactly the discarded modes' sum, so by the triangle
    inequality

        |h_full[t] - h_trunc[t]| <= sum_d |R_d| |lam_d|^(t-1)   (t >= 1)

    over the discarded set d (position 0 is exact — h0 is kept). This is a
    provable upper bound, not an estimate; a refit re-solves the kept
    residues and voids it. Returns
      * "curve"    (..., L)  per-position bound above;
      * "l1_bound" (...,)    sum_d |R_d| / (1 - |lam_d|) — the infinite-
        horizon l1 norm of the discard (inf for unstable discarded poles),
        which dominates sum_t curve[t] at every horizon;
      * "dropped"  (..., max(d-n, 0)) indices of the discarded modes
        (same h-inf influence ranking as `modal_truncation`).
    """
    a = jnp.exp(ssm.log_a)
    infl = jnp.abs(ssm.residues()) / jnp.clip(jnp.abs(1.0 - a), 1e-6)
    idx = jnp.argsort(-infl, axis=-1)[..., n:]
    take = lambda arr: jnp.take_along_axis(arr, idx, axis=-1)
    absR = jnp.abs(take(ssm.R_re) + 1j * take(ssm.R_im))
    mag = take(a)
    t = jnp.arange(L - 1, dtype=jnp.float32)
    tail = jnp.einsum("...d,...dl->...l", absR, mag[..., None] ** t)
    curve = jnp.concatenate([jnp.zeros_like(tail[..., :1]), tail], axis=-1)
    l1 = jnp.sum(jnp.where(mag < 1.0, absR / jnp.clip(1.0 - mag, 1e-9),
                           jnp.inf), axis=-1)
    return {"curve": curve, "l1_bound": l1, "dropped": idx}
