"""Self-speculative decoding on the distilled recurrence.

Invariants:
  * greedy speculative serving is token-for-token identical to
    non-speculative sequential generation, for every cache kind (distilled
    modal state / cached-conv kv / attention KV), every K in {1, 2, 4},
    including evictions mid-speculation (max-tokens and EOS landing inside
    a verify batch) and a garbage draft that diverges on token 1;
  * the rollback protocol is exact: snapshot -> decode j <= K tokens ->
    restore -> decode is BIT-identical to never having speculated, for
    every layer family (ring-buffer slot_pos included);
  * rejection-sampling verify preserves the filtered target support and
    bounds the acceptance count (hypothesis property test);
  * the per-(slot, token-index) PRNG key tree is path-independent, so the
    speculative and non-speculative samplers consume identical key streams.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ATTN, HYENA, LOCAL_ATTN, MAMBA2, RGLRU,
                                HyenaConfig, ModelConfig, RGLRUConfig,
                                SSMConfig)
from repro.core.distill import distill_model
from repro.core.modal import ModalSSM, eval_filter
from repro.distributed.sharding import unzip
from repro.models.model import (decode_step, init_cache, init_params,
                                materialize_conv_filters, prefill,
                                restore_cache_slots, snapshot_cache_slots,
                                write_cache_slot)
from repro.serve.engine import GenerationEngine
from repro.serve.sampling import filter_logits, sample_token_slots
from repro.serve.scheduler import ContinuousBatchingEngine, Request
from repro.serve.speculative import (make_draft_params, token_keys,
                                     verify_tokens)

MAX_LEN = 48
PROMPT_LENS = (4, 7, 12, 20, 9)
GEN_LENS = (8, 5, 11, 6, 9)       # none a multiple of K+1 -> evictions land
                                  # mid-verify-batch for every K tested


def _hyena_cfg(name="spec-hyena"):
    return ModelConfig(name=name, family="lcsm", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                       vocab=64, act="gelu", norm="layernorm",
                       pattern=(HYENA,),
                       hyena=HyenaConfig(n_filter_heads=2, filter_order=16,
                                         filter_emb=9, distill_order=8),
                       max_seq=512, dtype="float32")


def _attn_cfg(name="spec-attn", pattern=(ATTN,), window=0):
    return ModelConfig(name=name, family="dense", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                       vocab=64, act="gelu", norm="layernorm",
                       pattern=pattern, window=window, max_seq=512,
                       dtype="float32")


def _mamba_cfg(name="spec-mamba"):
    return ModelConfig(name=name, family="ssm", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                       vocab=64, act="gelu", norm="layernorm",
                       pattern=(MAMBA2,),
                       ssm=SSMConfig(d_state=8, head_dim=8, n_groups=1,
                                     expand=2, d_conv=4, chunk=4),
                       max_seq=512, dtype="float32")


def _rglru_cfg(name="spec-rglru"):
    return ModelConfig(name=name, family="hybrid", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                       vocab=64, act="gelu", norm="layernorm",
                       pattern=(RGLRU,), rglru=RGLRUConfig(d_conv=4, expand=1),
                       max_seq=512, dtype="float32")


@pytest.fixture(scope="module")
def hyena_model():
    cfg = _hyena_cfg()
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    params, _ = distill_model(params, cfg, steps=300, L=256)
    return cfg, params


@pytest.fixture(scope="module")
def attn_model():
    cfg = _attn_cfg()
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


@pytest.fixture(scope="module")
def local_model():
    # window < prompt+gen so the ring buffer wraps DURING speculation
    cfg = _attn_cfg("spec-local-id", pattern=(LOCAL_ATTN,), window=16)
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _prompts(vocab, lens=PROMPT_LENS, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32) for n in lens]


def _sequential_greedy(cfg, params, prompts, gens, mode):
    eng = GenerationEngine(params, cfg, max_len=MAX_LEN, mode=mode)
    return [np.asarray(eng.generate(jax.random.PRNGKey(1),
                                    jnp.asarray(p)[None], g)[0][0])
            for p, g in zip(prompts, gens)]


# ---------------------------------------------------------------------------
# Greedy speculative output == non-speculative output, token for token
# ---------------------------------------------------------------------------
# full (mode x K) matrix; the low-K combos of the non-flagship modes run in
# tier 2 (make test-all) — K=4 exercises the same executables plus the
# longer rollback window, so tier-1 keeps one spec compile per mode
_slow = pytest.mark.slow
IDENTITY_CASES = [
    ("distilled", "hyena", 1), ("distilled", "hyena", 2),
    ("distilled", "hyena", 4), ("cached_conv", "hyena", 4),
    ("distilled", "attn", 4), ("distilled", "local", 4),
    pytest.param("cached_conv", "hyena", 1, marks=_slow),
    pytest.param("cached_conv", "hyena", 2, marks=_slow),
    pytest.param("distilled", "attn", 1, marks=_slow),
    pytest.param("distilled", "attn", 2, marks=_slow),
    pytest.param("distilled", "local", 2, marks=_slow),
]


@pytest.mark.parametrize("mode,arch,K", IDENTITY_CASES)
def test_greedy_spec_matches_nonspec(hyena_model, attn_model, local_model,
                                     mode, arch, K):
    """Speculative serving (draft order 4 of 8) emits exactly the tokens of
    sequential non-speculative generation, for every cache kind and every K
    — including the windowed-attention ring, whose buffer wraps DURING a
    verify batch once the context exceeds the window. GEN_LENS are chosen
    so max-token evictions land mid-verify-batch (the remaining speculated
    tokens must be dropped)."""
    cfg, params = {"hyena": hyena_model, "attn": attn_model,
                   "local": local_model}[arch]
    prompts = _prompts(cfg.vocab)
    want = _sequential_greedy(cfg, params, prompts, GEN_LENS, mode)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode=mode, spec_k=K, draft_order=4)
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(prompts, GEN_LENS)]
    eng.run()
    for r, w in zip(reqs, want):
        assert r.status == "finished" and r.finish_reason == "max_tokens"
        np.testing.assert_array_equal(np.asarray(r.tokens), w)
    assert eng.stats["spec_rounds"] > 0
    assert 0 <= eng.stats["spec_accepted"] <= eng.stats["spec_drafted"]


def test_eos_eviction_mid_speculation(hyena_model):
    """EOS produced inside a verify batch stops the request AT the EOS token
    — later accepted tokens from the same round are dropped."""
    cfg, params = hyena_model
    p = _prompts(cfg.vocab)[0]
    ref = _sequential_greedy(cfg, params, [p], [8], "distilled")[0]
    eos = int(ref[2])                       # fires mid-batch for K=4
    eng = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=MAX_LEN,
                                   spec_k=4, draft_order=4)
    req = eng.submit(p, max_new_tokens=8, eos_id=eos)
    eng.run()
    assert req.finish_reason == "eos"
    np.testing.assert_array_equal(np.asarray(req.tokens), ref[:3])


def test_diverging_draft_still_exact(hyena_model):
    """A garbage draft (random weights — diverges on token 1, acceptance ~0)
    must not change the OUTPUT: the verifier's correction tokens alone
    reproduce non-speculative generation."""
    cfg, params = hyena_model
    garbage, _ = unzip(init_params(jax.random.PRNGKey(123), cfg))
    prompts = _prompts(cfg.vocab)[:3]
    gens = GEN_LENS[:3]
    want = _sequential_greedy(cfg, params, prompts, gens, "distilled")
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   spec_k=4, draft_order=4,
                                   draft_model=(garbage, cfg))
    reqs = [eng.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    eng.run()
    for r, w in zip(reqs, want):
        np.testing.assert_array_equal(np.asarray(r.tokens), w)
    # the garbage draft gets (almost) nothing accepted
    assert eng.stats["spec_accepted"] <= eng.stats["spec_drafted"] * 0.3


def test_mixed_spec_and_nonspec_slots(hyena_model):
    """A request that opts out of speculation (Request.spec=False) coexists
    with speculating slots and still matches sequential generation."""
    cfg, params = hyena_model
    prompts = _prompts(cfg.vocab)[:2]
    want = _sequential_greedy(cfg, params, prompts, GEN_LENS[:2], "distilled")
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   spec_k=4, draft_order=4)
    r0 = eng.submit(prompts[0], max_new_tokens=GEN_LENS[0])
    r1 = eng.submit_request(Request(rid=99, prompt=prompts[1],
                                    max_new_tokens=GEN_LENS[1], spec=False))
    eng.run()
    np.testing.assert_array_equal(np.asarray(r0.tokens), want[0])
    np.testing.assert_array_equal(np.asarray(r1.tokens), want[1])


# ---------------------------------------------------------------------------
# Rollback exactness: snapshot -> decode -> restore -> decode is bit-exact
# ---------------------------------------------------------------------------
ROLLBACK_FAMILIES = [
    ("hyena-distilled", _hyena_cfg, "native"),
    ("hyena-cachedconv", _hyena_cfg, "conv"),
    ("attn-linear", _attn_cfg, "native"),
    ("attn-ring", lambda: _attn_cfg("spec-local", pattern=(LOCAL_ATTN,),
                                    window=16), "native"),
    ("mamba2", _mamba_cfg, "native"),
    ("rglru", _rglru_cfg, "native"),
]


@pytest.mark.parametrize("name,mkcfg,kind",
                         ROLLBACK_FAMILIES,
                         ids=[f[0] for f in ROLLBACK_FAMILIES])
def test_snapshot_restore_is_bit_exact(name, mkcfg, kind):
    """snapshot -> decode j <= K tokens -> restore -> decode produces
    BIT-identical logits and caches to never having speculated — per layer
    family, which pins down ring-buffer slot_pos rollback in particular."""
    K, j = 4, 3
    cfg = mkcfg()
    params, _ = unzip(init_params(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    lens = [8, 16, 12] if cfg.ssm is not None else [5, 9, 7]
    B = len(lens)
    pool, _ = unzip(init_cache(cfg, B, MAX_LEN, cache_kind=kind,
                               per_slot=True))
    filters = (materialize_conv_filters(params, cfg, MAX_LEN)
               if cfg.hyena is not None and kind == "conv" else None)
    for b, L in enumerate(lens):
        p = rng.integers(0, cfg.vocab, size=L).astype(np.int32)
        single, _ = prefill(params, jnp.asarray(p)[None], cfg,
                            max_len=MAX_LEN, cache_kind=kind)
        pool = write_cache_slot(pool, single, b)

    def advance(cache, n, seed):
        toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(n, B)), jnp.int32)
        lgs = []
        for t in range(n):
            cache, lg = decode_step(params, cache, toks[t][:, None], cfg,
                                    conv_filters=filters)
            lgs.append(np.asarray(lg))
        return cache, lgs

    # reference: decode 2 tokens with no speculation in between
    rng2 = np.random.default_rng(7)
    cont = jnp.asarray(rng2.integers(0, cfg.vocab, size=(2, B)), jnp.int32)

    def run_cont(cache):
        lgs = []
        for t in range(2):
            cache, lg = decode_step(params, cache, cont[t][:, None], cfg,
                                    conv_filters=filters)
            lgs.append(np.asarray(lg))
        return cache, lgs

    want_cache, want_lgs = run_cont(pool)

    snap = snapshot_cache_slots(pool, cfg, K)
    spec, _ = advance(pool, j, seed=3)          # speculate j <= K tokens
    rolled = restore_cache_slots(spec, snap, cfg)
    got_cache, got_lgs = run_cont(rolled)

    for a, b_ in zip(want_lgs, got_lgs):
        assert np.array_equal(a, b_), name
    for (path, a), (_, b_) in zip(
            jax.tree_util.tree_leaves_with_path(want_cache),
            jax.tree_util.tree_leaves_with_path(got_cache)):
        assert np.array_equal(np.asarray(a), np.asarray(b_)), (name, path)


# ---------------------------------------------------------------------------
# Rejection-sampling verify: support + acceptance-count properties
# ---------------------------------------------------------------------------
def _run_verify(seed, B, K, V, temps, top_k, top_p, spec_len=None):
    rng = np.random.default_rng(seed)
    tl = jnp.asarray(rng.normal(size=(B, K + 1, V)) * 3, jnp.float32)
    dl = jnp.asarray(rng.normal(size=(B, K, V)) * 3, jnp.float32)
    temps = jnp.asarray(temps, jnp.float32)
    top_k = jnp.asarray(top_k, jnp.int32)
    top_p = jnp.asarray(top_p, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(seed), B)
    tok_idx = jnp.asarray(rng.integers(0, 100, size=B), jnp.int32)
    # drafts proposed from the draft's filtered distribution (q > 0)
    qf = filter_logits(dl.reshape(B * K, V),
                       temperature=jnp.repeat(jnp.clip(temps, 1e-3), K),
                       top_k=jnp.repeat(top_k, K),
                       top_p=jnp.repeat(top_p, K))
    drafts = jax.vmap(jax.random.categorical)(
        jax.random.split(jax.random.PRNGKey(seed + 1), B * K),
        qf).reshape(B, K).astype(jnp.int32)
    tokens = jnp.concatenate([jnp.zeros((B, 1), jnp.int32), drafts], axis=1)
    sl = (jnp.full((B,), K + 1, jnp.int32) if spec_len is None
          else jnp.asarray(spec_len, jnp.int32))
    emitted, n_emit, n_acc, corr = verify_tokens(
        tl, dl, tokens, sl, temperature=temps, top_k=top_k, top_p=top_p,
        slot_keys=keys, tok_idx=tok_idx)
    return (np.asarray(emitted), np.asarray(n_emit), np.asarray(n_acc),
            np.asarray(corr), tl, tokens, temps, top_k, top_p, np.asarray(sl))


def _check_verify_props(out):
    emitted, n_emit, n_acc, corr, tl, tokens, temps, top_k, top_p, sl = out
    B, C, V = tl.shape
    K = C - 1
    assert ((1 <= n_emit) & (n_emit <= sl)).all()
    assert ((0 <= n_acc) & (n_acc <= K)).all()
    pf = np.asarray(filter_logits(
        jnp.asarray(tl.reshape(B * C, V)),
        temperature=jnp.repeat(jnp.clip(temps, 1e-3), C),
        top_k=jnp.repeat(top_k, C),
        top_p=jnp.repeat(top_p, C))).reshape(B, C, V)
    for b in range(B):
        r = n_acc[b]
        # accepted prefix = the drafts, then the correction token
        np.testing.assert_array_equal(emitted[b, :r],
                                      np.asarray(tokens)[b, 1:r + 1])
        assert emitted[b, r] == corr[b]
        if float(temps[b]) <= 0.0:
            assert corr[b] == int(np.argmax(tl[b, r]))
        else:
            # correction lies inside the FILTERED target support at its
            # position (residual support is a subset of it)
            assert np.isfinite(pf[b, r, corr[b]])


def test_verify_tokens_basic_properties():
    out = _run_verify(0, B=4, K=4, V=32,
                      temps=[0.0, 1.0, 0.7, 2.0], top_k=[0, 0, 5, 0],
                      top_p=[1.0, 1.0, 1.0, 0.8])
    _check_verify_props(out)
    # spec_len = 1 rows behave like plain decode: exactly one token emitted
    out = _run_verify(1, B=3, K=4, V=16, temps=[0.0, 1.0, 0.5],
                      top_k=[0, 3, 0], top_p=[1.0, 1.0, 0.9],
                      spec_len=[1, 1, 5])
    emitted, n_emit = out[0], out[1]
    assert n_emit[0] == 1 and n_emit[1] == 1


def test_verify_tokens_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4),
           st.lists(st.floats(0.0, 3.0), min_size=3, max_size=3),
           st.lists(st.integers(0, 8), min_size=3, max_size=3),
           st.lists(st.floats(0.1, 1.0), min_size=3, max_size=3))
    @settings(max_examples=25, deadline=None)
    def prop(seed, K, temps, top_k, top_p):
        out = _run_verify(seed, B=3, K=K, V=16, temps=temps, top_k=top_k,
                          top_p=top_p)
        _check_verify_props(out)

    prop()


# ---------------------------------------------------------------------------
# PRNG key tree: spec and non-spec consume identical streams
# ---------------------------------------------------------------------------
def test_token_key_tree_is_path_independent():
    base = jax.random.PRNGKey(0)
    slot_keys = jnp.stack([jax.random.fold_in(base, rid) for rid in (3, 7)])
    t = jnp.asarray([5, 9], jnp.int32)
    got = token_keys(slot_keys, t, 1)
    for b, (rid, ti) in enumerate([(3, 5), (7, 9)]):
        want = jax.random.fold_in(
            jax.random.fold_in(jax.random.fold_in(base, rid), ti), 1)
        np.testing.assert_array_equal(np.asarray(got[b]), np.asarray(want))


def test_sample_token_slots_per_row_keys():
    """Per-row keys: a row's draw depends only on its own key (the spec
    verifier re-draws from the same split key per verify position)."""
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 32)),
                        jnp.float32)
    temps = jnp.full((3,), 1.0)
    tks = jnp.zeros((3,), jnp.int32)
    tps = jnp.ones((3,), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    a = sample_token_slots(keys, logits, temperature=temps, top_k=tks,
                           top_p=tps)
    keys2 = keys.at[2].set(jax.random.PRNGKey(42))     # perturb another row
    b = sample_token_slots(keys2, logits, temperature=temps, top_k=tks,
                           top_p=tps)
    assert int(a[0]) == int(b[0]) and int(a[1]) == int(b[1])


# ---------------------------------------------------------------------------
# Draft construction: embedded truncation == compact truncation
# ---------------------------------------------------------------------------
def test_embedded_draft_matches_compact_truncation(hyena_model):
    """The state-sharing draft (full-order arrays, zeroed dropped residues)
    realizes exactly the same filter as the compact order-d truncation, and
    keeps every pole untouched (the property that lets it read the serving
    cache)."""
    cfg, params = hyena_model
    emb, emb_cfg = make_draft_params(params, cfg, 4, embed=True)
    cmp_, cmp_cfg = make_draft_params(params, cfg, 4, embed=False)
    assert emb_cfg == cfg
    assert cmp_cfg.hyena.distill_order == 4
    dp0 = params["groups"]["l0"]["mix"]["distilled"]
    dpe = emb["groups"]["l0"]["mix"]["distilled"]
    dpc = cmp_["groups"]["l0"]["mix"]["distilled"]
    np.testing.assert_array_equal(np.asarray(dpe["log_a"]),
                                  np.asarray(dp0["log_a"]))   # poles shared
    # exactly order/2 modes carry nonzero residues per filter
    nz = (np.abs(np.asarray(dpe["R_re"])) +
          np.abs(np.asarray(dpe["R_im"])) > 0).sum(-1)
    assert (nz <= 2).all()
    L = 64
    he = eval_filter(ModalSSM(dpe["log_a"], dpe["theta"], dpe["R_re"],
                              dpe["R_im"], dpe["h0"]), L)
    hc = eval_filter(ModalSSM(dpc["log_a"], dpc["theta"], dpc["R_re"],
                              dpc["R_im"], dpc["h0"]), L)
    np.testing.assert_allclose(np.asarray(he), np.asarray(hc), rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# Top-k tree drafts
# ---------------------------------------------------------------------------
def _spec_round_inputs(cfg, params, mode, B=3, plen=8, seed=5):
    """A small pooled decode state (B slots, all greedy) plus per-slot PRNG
    metadata, built through prefill like the engine does."""
    from repro.models.model import init_cache, prefill, write_cache_slots
    from repro.distributed.sharding import unzip as _unzip
    kind = "conv" if mode == "cached_conv" else "native"
    cache, _ = _unzip(init_cache(cfg, B, MAX_LEN, cache_kind=kind,
                                 per_slot=True))
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, plen)), jnp.int32)
    c1, logits = prefill(params, toks, cfg, max_len=MAX_LEN, cache_kind=kind)
    cache = write_cache_slots(cache, c1, jnp.arange(B, dtype=jnp.int32))
    last = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    base = jax.random.PRNGKey(3)
    keys = jnp.stack([jax.random.fold_in(base, r) for r in range(B)])
    meta = dict(temperature=jnp.zeros((B,), jnp.float32),
                top_k=jnp.zeros((B,), jnp.int32),
                top_p=jnp.ones((B,), jnp.float32), slot_keys=keys,
                tok_idx=jnp.zeros((B,), jnp.int32))
    filters = (materialize_conv_filters(params, cfg, MAX_LEN)
               if kind == "conv" else None)
    return cache, last, meta, filters


@pytest.mark.parametrize("mode,arch", [("distilled", "hyena"),
                                       ("distilled", "attn")])
def test_tree_branch1_equals_chain(hyena_model, attn_model, mode, arch):
    """spec_round_tree at branching factor 1 is the chain round: same
    emitted tokens, same per-row counts, same committed cache — on both the
    selection-commit path (pure distilled Hyena) and the generic
    snapshot/replay path (attention)."""
    from repro.serve.speculative import (make_draft_params as _mk,
                                         spec_round, spec_round_tree)
    from repro.models.model import supports_state_select
    cfg, params = {"hyena": hyena_model, "attn": attn_model}[arch]
    dparams, dcfg = _mk(params, cfg, 4, embed=True)
    cache, last, meta, filters = _spec_round_inputs(cfg, params, mode)
    sel = supports_state_select(cfg)
    spec_len = jnp.full((3,), 5, jnp.int32)
    out_c = spec_round(params, dparams, cache, last, spec_len, None, 4, cfg,
                       dcfg, conv_filters=filters, select_commit=sel, **meta)
    out_t = spec_round_tree(params, dparams, cache, last, spec_len, None, 4,
                            1, cfg, dcfg, conv_filters=filters,
                            select_commit=sel, **meta)
    for name, c, t in (("emitted", out_c[2], out_t[2]),
                       ("n_emit", out_c[3], out_t[3]),
                       ("correction", out_c[4], out_t[4]),
                       ("tok_idx", out_c[5], out_t[5])):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(t), err_msg=name)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        out_c[0], out_t[0])


@pytest.mark.parametrize("mode,arch", [
    ("distilled", "hyena"), ("cached_conv", "hyena"),
    pytest.param("distilled", "attn", marks=_slow)])
def test_tree_branch2_greedy_identity(hyena_model, attn_model, mode, arch):
    """Greedy output with branch-2 tree drafts is token-identical to plain
    sequential generation: side chains only ever replace a rejected chain-0
    suffix with a LONGER correct prefix of the same target argmax sequence."""
    cfg, params = {"hyena": hyena_model, "attn": attn_model}[arch]
    prompts = _prompts(cfg.vocab)[:3]
    gens = GEN_LENS[:3]
    want = _sequential_greedy(cfg, params, prompts, gens, mode)
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   mode=mode, spec_k=4, draft_order=4,
                                   spec_branch=2)
    reqs = [eng.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    eng.run()
    for r, w in zip(reqs, want):
        np.testing.assert_array_equal(np.asarray(r.tokens), w)
    assert eng.stats["spec_rounds"] > 0


# ---------------------------------------------------------------------------
# Acceptance-driven control: window law, identity under window changes
# ---------------------------------------------------------------------------
def test_controller_window_law():
    from repro.serve.speculative import (SlotSpecController,
                                         SpecControllerConfig)
    ctl = SlotSpecController(2, 4, SpecControllerConfig(
        ema=0.0, min_rounds=1, probe_every=3))   # ema=0: window from the
    ctl.admit(0, True)                           # latest round alone
    ctl.admit(1, False)                          # opted out
    assert ctl.on_round(0) == 5 and ctl.on_round(1) == 1
    assert ctl.observe(0, 4, 4) == 5             # full acceptance: full K
    assert ctl.observe(0, 4, 1) < 5              # partial: shrink
    assert ctl.observe(0, 4, 0) == 1             # none: disable
    # disabled slot probes at depth 1 every probe_every rounds
    probes = [ctl.on_round(0) for _ in range(6)]
    assert probes.count(2) == 2 and set(probes) <= {1, 2}
    # a successful probe re-enables
    assert ctl.observe(0, 1, 1) > 1
    # the opted-out slot never probes
    assert all(ctl.on_round(1) == 1 for _ in range(8))


def test_adaptive_windows_keep_identity(hyena_model):
    """With the controller shrinking windows and toggling speculation off and
    back on per slot (garbage draft -> acceptance collapses -> disable ->
    depth-1 probes), greedy output stays token-identical to plain decoding
    and the engine actually exercised window changes."""
    from repro.serve.speculative import SpecControllerConfig
    cfg, params = hyena_model
    garbage, _ = unzip(init_params(jax.random.PRNGKey(123), cfg))
    prompts = _prompts(cfg.vocab)
    want = _sequential_greedy(cfg, params, prompts, GEN_LENS, "distilled")
    eng = ContinuousBatchingEngine(
        params, cfg, n_slots=2, max_len=MAX_LEN, spec_k=4, draft_order=4,
        draft_model=(garbage, cfg),
        spec_adapt=SpecControllerConfig(ema=0.0, min_rounds=1,
                                        probe_every=2))
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(prompts, GEN_LENS)]
    eng.run()
    for r, w in zip(reqs, want):
        np.testing.assert_array_equal(np.asarray(r.tokens), w)
    assert eng.stats["spec_window_syncs"] > 0


@pytest.mark.parametrize("mode,arch", [("cached_conv", "hyena"),
                                       ("distilled", "local")])
def test_adaptive_windows_other_cache_kinds(hyena_model, local_model, mode,
                                            arch):
    """Window changes mid-stream stay exact for the separate-draft-pool
    (cached-conv) and ring-buffer (windowed attention) cache kinds too."""
    from repro.serve.speculative import SpecControllerConfig
    cfg, params = {"hyena": hyena_model, "local": local_model}[arch]
    prompts = _prompts(cfg.vocab)[:3]
    gens = GEN_LENS[:3]
    want = _sequential_greedy(cfg, params, prompts, gens, mode)
    eng = ContinuousBatchingEngine(
        params, cfg, n_slots=2, max_len=MAX_LEN, mode=mode, spec_k=4,
        draft_order=4,
        spec_adapt=SpecControllerConfig(ema=0.3, min_rounds=1,
                                        disable_below=0.5, probe_every=2))
    reqs = [eng.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    eng.run()
    for r, w in zip(reqs, want):
        np.testing.assert_array_equal(np.asarray(r.tokens), w)


# ---------------------------------------------------------------------------
# Accounting: drafted tokens are counted at dispatch, not at retire
# ---------------------------------------------------------------------------
def test_eviction_before_apply_counts_drafted(hyena_model):
    """A slot evicted between a speculative dispatch and its retire must
    keep its drafted tokens in the denominator (the old retire-time counter
    silently dropped them, inflating acceptance_rate)."""
    cfg, params = hyena_model
    p = _prompts(cfg.vocab)[0]
    eng = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=MAX_LEN,
                                   spec_k=4, draft_order=8, spec_adapt=False,
                                   overlap=False)
    req = eng.submit(p, max_new_tokens=20)
    eng.step()                                   # admits + first spec round
    assert req.status == "running"
    pending = eng._dispatch_spec()
    drafted = eng.stats["spec_drafted"]
    accepted = eng.stats["spec_accepted"]
    assert drafted >= 4                          # this round already counted
    eng._evict(req.slot, "test")                 # evicted before apply
    assert eng._retire(pending) == 0             # tokens dropped...
    assert eng.stats["spec_drafted"] == drafted  # ...but drafts still count
    assert eng.stats["spec_accepted"] == accepted


# ---------------------------------------------------------------------------
# Autotuning
# ---------------------------------------------------------------------------
def test_autotune_margin(hyena_model):
    """An unreachable margin yields chosen=None (speculation off); margin 0
    with the full-order draft in the pool picks a winner. The report table
    is JSON-serializable for BENCH_serve.json."""
    import json
    from repro.serve.speculative import SpecCandidate, autotune_spec
    cfg, params = hyena_model
    rep = autotune_spec(params, cfg, n_slots=2, max_len=MAX_LEN,
                        prompt_len=8, target_tokens=24, margin=1e9,
                        candidates=[SpecCandidate(2, 8)])
    assert rep.chosen is None
    assert "plain" in rep.pretty()
    json.dumps(rep.table())
    rep2 = autotune_spec(params, cfg, n_slots=2, max_len=MAX_LEN,
                         prompt_len=8, target_tokens=24, margin=0.0,
                         candidates=[SpecCandidate(2, 8),
                                     SpecCandidate(2, 4, branch=2)])
    assert rep2.chosen is not None
    assert len(rep2.table()) == 3


def test_engine_spec_auto(hyena_model):
    """spec_k='auto' resolves to the measured winner (full-order draft in
    the candidate pool -> speculation on) and still matches plain greedy
    output; spec_k='bogus' is rejected."""
    from repro.serve.speculative import SpecCandidate
    cfg, params = hyena_model
    prompts = _prompts(cfg.vocab)[:2]
    want = _sequential_greedy(cfg, params, prompts, GEN_LENS[:2], "distilled")
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                   spec_k="auto", spec_margin=0.0,
                                   spec_candidates=[SpecCandidate(2, 8)])
    assert eng.spec_report is not None
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(prompts, GEN_LENS[:2])]
    eng.run()
    for r, w in zip(reqs, want):
        np.testing.assert_array_equal(np.asarray(r.tokens), w)
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN,
                                 spec_k="bogus")


def test_stream_metrics_are_ints(hyena_model):
    """run_request_stream emits integer request/token counts (BENCH_serve
    type normalization)."""
    from repro.serve.scheduler import (run_request_stream,
                                       synthesize_request_stream)
    cfg, params = hyena_model
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=MAX_LEN)
    stream = synthesize_request_stream(
        np.random.default_rng(0), 3, rate=100.0, prompt_lens=(8, 12),
        gen_tokens=(2, 4), vocab=cfg.vocab)
    m = run_request_stream(eng, stream)
    assert type(m["n_requests"]) is int and type(m["n_tokens"]) is int
