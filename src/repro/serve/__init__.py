from repro.serve.engine import GenerationEngine                    # noqa: F401
from repro.serve.metrics import (MetricsRegistry, count_compiles,  # noqa: F401
                                 speculative_summary,
                                 start_metrics_server)
from repro.serve.sampling import sample_token, sample_token_slots  # noqa: F401
from repro.serve.scheduler import (ContinuousBatchingEngine,       # noqa: F401
                                   Request, SamplingParams,
                                   run_request_stream,
                                   synthesize_request_stream)
from repro.serve.trace import NULL_TRACER, Tracer                  # noqa: F401
