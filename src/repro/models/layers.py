"""Shared neural-net layers (raw JAX, no framework deps)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Param, ShardingRules, constrain


@dataclasses.dataclass
class ShardCtx:
    """Threaded through model code to apply activation sharding constraints."""
    mesh: Optional[object] = None
    rules: Optional[ShardingRules] = None

    def cs(self, x, axes):
        if self.mesh is None or self.rules is None:
            return x
        return constrain(x, axes, self.rules, self.mesh)


NOCTX = ShardCtx()


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, axes, in_dim=None, dtype=jnp.float32) -> Param:
    in_dim = in_dim if in_dim is not None else shape[0]
    scale = 1.0 / np.sqrt(max(in_dim, 1))
    w = jax.random.normal(key, shape, dtype) * scale
    return Param(w, tuple(axes))


def zeros_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), tuple(axes))


def ones_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), tuple(axes))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(kind: str, d: int):
    if kind == "rmsnorm":
        return {"scale": ones_init((d,), ("act_embed",))}
    return {"scale": ones_init((d,), ("act_embed",)),
            "bias": zeros_init((d,), ("act_embed",))}


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (including Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float,
               m_rope_sections: Optional[Tuple[int, ...]] = None):
    """x: (..., S, H, hd); positions: (..., S) int32 (or (...,3,S) for m-rope).

    For M-RoPE with text-only inputs all three position streams coincide, so
    we accept (..., S) and broadcast across sections — this matches Qwen2-VL
    semantics for pure-text spans while keeping the sectioned layout.
    """
    if theta <= 0.0:
        return x
    hd = x.shape[-1]
    inv = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    pos = positions.astype(jnp.float32)[..., None]    # (..., S, 1)
    ang = pos * inv                                   # (..., S, hd/2)
    if m_rope_sections:
        # section s of the rotary dims uses position stream s; with shared
        # positions the angles are identical, but we keep the structure.
        sec = np.zeros(hd // 2, dtype=np.int32)
        start = 0
        for i, width in enumerate(m_rope_sections):
            sec[start:start + width] = i
            start += width
        ang = ang  # shared positions: streams coincide (text-only stand-in)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, f: int, act: str):
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {
            "wi": dense_init(k1, (d, 2, f), ("embed", None, "mlp"), in_dim=d),
            "wo": dense_init(k2, (f, d), ("mlp", "embed"), in_dim=f),
        }
    return {
        "wi": dense_init(k1, (d, f), ("embed", "mlp"), in_dim=d),
        "wo": dense_init(k2, (f, d), ("mlp", "embed"), in_dim=f),
    }


def apply_mlp(params, x, act: str, ctx: ShardCtx = NOCTX):
    if act in ("swiglu", "geglu"):
        h = jnp.einsum("...d,dgf->...gf", x, params["wi"].astype(x.dtype))
        gate, up = h[..., 0, :], h[..., 1, :]
        g = jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate)
        h = g * up
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype)))
    h = ctx.cs(h, ("batch", None, "mlp"))
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embed(key, vocab: int, d: int, tie: bool, max_seq: int = 0,
               learned_pos: bool = False):
    keys = jax.random.split(key, 3)
    p = {"tok": Param(jax.random.normal(keys[0], (vocab, d)) * 0.02,
                      ("vocab", "embed"))}
    if not tie:
        p["unembed"] = dense_init(keys[1], (d, vocab), ("embed", "vocab"), in_dim=d)
    if learned_pos:
        p["pos"] = Param(jax.random.normal(keys[2], (max_seq, d)) * 0.02,
                         (None, "embed"))
    return p


def embed_tokens(params, tokens, ctx: ShardCtx = NOCTX, dtype=jnp.bfloat16):
    x = jnp.take(params["tok"], tokens, axis=0).astype(dtype)
    return ctx.cs(x, ("batch", None, "act_embed"))


def unembed(params, x, tie: bool, softcap: float = 0.0, ctx: ShardCtx = NOCTX):
    if tie:
        logits = jnp.einsum("...d,vd->...v", x, params["tok"].astype(x.dtype))
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["unembed"].astype(x.dtype))
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return ctx.cs(logits, ("batch", None, "vocab"))


# ---------------------------------------------------------------------------
# Depthwise causal short conv (Hyena / Mamba / RG-LRU frontends)
# ---------------------------------------------------------------------------
def init_short_conv(key, d: int, width: int):
    w = jax.random.normal(key, (width, d)) / np.sqrt(width)
    return {"w": Param(w, ("conv", "act_embed"))}


def apply_short_conv(params, x):
    """x: (B, S, D) -> causal depthwise conv, same length."""
    w = params["w"].astype(x.dtype)                    # (W, D)
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out


def short_conv_step(params, cache, u):
    """Single-token conv step. cache: (B, W-1, D); u: (B, D)."""
    w = params["w"].astype(u.dtype)
    width = w.shape[0]
    window = jnp.concatenate([cache, u[:, None, :]], axis=1)  # (B, W, D)
    y = jnp.einsum("bwd,wd->bd", window, w)
    new_cache = window[:, 1:, :] if width > 1 else cache
    return new_cache, y


def conv_tail_gather(x, width: int, lengths):
    """Last `width` rows of x (B, S, D) ending at each row's true length —
    the short-conv tail a decode cache carries. Positions before the start
    of the sequence (length < width) are zeros, matching the causal conv's
    left zero-padding. lengths=None means every row is full length."""
    if lengths is None:
        return x[:, x.shape[1] - width:, :]
    idx = lengths[:, None] - width + jnp.arange(width)[None, :]     # (B, W)
    out = jnp.take_along_axis(x, jnp.clip(idx, 0)[..., None], axis=1)
    return jnp.where(idx[..., None] >= 0, out, 0)


def short_conv_chunk(params, tail, x, chunk_len=None):
    """Chunked causal conv with a carried tail (resumable prefill).

    tail: (B, W-1, D) — the W-1 inputs preceding this chunk (zeros for the
    first chunk, which makes chunk 0 bit-identical to `apply_short_conv`);
    x: (B, C, D). Returns (new_tail, y (B, C, D)). `chunk_len` (traced
    scalar) marks how many of the C positions are real: the new tail is the
    W-1 inputs ending at `chunk_len`, so a padded final chunk leaves the
    carried state exactly where the prompt ends.
    """
    w = params["w"].astype(x.dtype)
    width = w.shape[0]
    C = x.shape[1]
    ext = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # (B, W-1+C, D)
    y = jnp.zeros_like(x)
    for i in range(width):
        y = y + ext[:, i:i + C, :] * w[i]
    if width == 1:
        return tail, y
    if chunk_len is None:
        new_tail = ext[:, C:, :]
    else:
        idx = chunk_len + jnp.arange(width - 1)       # ext[chunk_len : +W-1]
        new_tail = jnp.take(ext, idx, axis=1)
    return new_tail, y
