"""Sec. 5.4 "SSM state dimension and throughput": decode-step latency vs the
distillation order d (paper: <2% effect below d=100)."""
import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from benchmarks.models import build, hyena_cfg
from repro.models.model import decode_step, init_cache
from repro.distributed.sharding import unzip

BATCH = 16


def main(out):
    base = None
    for d in (4, 8, 16, 32, 64):
        cfg = hyena_cfg(distill_order=d)
        params = build(cfg, distill=False)     # random modal params: same cost
        cache, _ = unzip(init_cache(cfg, BATCH, 64))
        tok = jnp.ones((BATCH, 1), jnp.int32)
        step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
        dt = timeit(step, params, cache, tok, warmup=2, iters=10)
        if base is None:
            base = dt
        out(row(f"sec5.4/state_dim/d{d}", dt * 1e6,
                f"rel={dt/base:.2f}"))
