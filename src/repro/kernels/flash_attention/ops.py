"""Public wrapper: blocked causal GQA flash attention."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, use_pallas=None,
                    qb=128, kb=128):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      qb=qb, kb=kb, interpret=not _on_tpu())
    return flash_attention_ref(q, k, v, causal=causal, window=window)
